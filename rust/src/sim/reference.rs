//! Frozen pre-refactor simulation path — the equivalence oracle for the
//! [`crate::balancer`] trait API.
//!
//! This module is a verbatim copy of the `match`-on-[`Policy`] simulator
//! that shipped before the balancer refactor (PR 3): planning, prophet
//! observation, drift bookkeeping and comm-style selection all inlined as
//! enum arms.  The closed [`Policy`] enum itself now lives HERE (the
//! public `sim::Policy` migration shim is fully retired): it is the
//! oracle's input vocabulary, nothing else.  The trait-based driver in
//! [`super`] must reproduce this module's [`SimReport`]s bit-for-bit;
//! the golden test (`rust/tests/golden_equivalence.rs`) pins that by
//! driving both sides directly.
//!
//! **Behaviorally frozen** — like `planner::greedy_search_reference`, this
//! code must not be "improved".  If policy SEMANTICS ever change on
//! purpose, change both implementations in lockstep or retire the oracle
//! (see ROADMAP).

use crate::balancer::ProphetOptions;
use crate::cluster::ClusterSpec;
use crate::config::ModelSpec;
use crate::metrics::balance_degree;
use crate::moe::{LoadMatrix, Placement};
use crate::perfmodel::PerfModel;
use crate::planner::{greedy_search, policies, Planner};
use crate::prophet::Prophet;
use crate::scheduler::{build_blocking, build_blockwise, BlockCosts, LoadBalanceOps};
use crate::sim::{Engine, IterationResult, SimReport};
use crate::util::threads;
use crate::workload::Trace;
use std::sync::Arc;

/// The closed pre-refactor policy vocabulary, preserved as the oracle's
/// input side.  Use [`crate::balancer::registry`] everywhere else.
#[derive(Clone, Debug)]
pub enum Policy {
    /// Deepspeed-MoE: pure EP, no load balancing.
    DeepspeedMoe,
    /// FasterMoE: dynamic shadowing to ALL devices, blocking timeline.
    FasterMoe,
    /// Replicate the k heaviest experts to all devices (Fig 15 top2/top3).
    TopK(usize),
    /// Pro-Prophet (planner + optional scheduler).
    ProProphet(ProphetOptions),
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::DeepspeedMoe => "Deepspeed-MoE".into(),
            Policy::FasterMoe => "FasterMoE".into(),
            Policy::TopK(k) => format!("top{k}"),
            Policy::ProProphet(o) => {
                if o.scheduler_on && o.planner.use_overlap_model {
                    "Pro-Prophet".into()
                } else if o.scheduler_on {
                    "Pro-Prophet(no-comb)".into()
                } else {
                    "Pro-Prophet(planner)".into()
                }
            }
        }
    }
}

/// Per-layer planning + pricing outcome (pre-refactor shape).
struct LayerOutcome {
    costs: BlockCosts,
    bal_before: f64,
    bal_after: f64,
    trans_copies: u64,
}

fn plan_and_price(
    layer: usize,
    w: &LoadMatrix,
    policy: &Policy,
    pm: &PerfModel,
    eng: &Engine,
    planner: Option<&mut Planner>,
    prophet: Option<&Prophet>,
) -> LayerOutcome {
    let (placement, plan_cost): (Arc<Placement>, f64) = match policy {
        Policy::DeepspeedMoe => {
            (Arc::new(Placement::identity(w.n_experts(), w.n_devices())), 0.0)
        }
        Policy::FasterMoe => {
            (Arc::new(policies::fastermoe_shadowing(w, pm)), pm.t_plan)
        }
        Policy::TopK(k) => (Arc::new(policies::top_k_to_all(w, *k)), 0.0),
        Policy::ProProphet(_) => {
            let planner = planner.expect("Pro-Prophet pricing needs a planner");
            let forecast = prophet.and_then(|p| p.forecast_matrix(layer));
            let w_plan: &LoadMatrix = forecast.as_ref().unwrap_or(w);
            let before = planner.plans_run;
            let p = planner.plan(w_plan, pm);
            let cost = if planner.plans_run > before { pm.t_plan } else { 0.0 };
            (p, cost)
        }
    };
    let routed_before = w.route_identity();
    let routed_after = w.route(&placement);
    let unicast = matches!(policy, Policy::FasterMoe | Policy::TopK(_));
    LayerOutcome {
        costs: eng.block_costs_styled(w, &placement, plan_cost, unicast),
        bal_before: balance_degree(&routed_before.h),
        bal_after: balance_degree(&routed_after.h),
        trans_copies: placement.transfer_copies(),
    }
}

/// The pre-refactor `sim::simulate`, preserved bit-for-bit.
pub fn simulate_reference(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    policy: &Policy,
) -> SimReport {
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let n_layers = trace.n_layers;

    let mut planners: Vec<Planner> = match policy {
        Policy::ProProphet(o) => (0..n_layers).map(|_| Planner::new(o.planner.clone())).collect(),
        _ => vec![],
    };
    let mut prophet: Option<Prophet> = match policy {
        Policy::ProProphet(o) => Some(Prophet::new(o.prophet.clone(), n_layers)),
        _ => None,
    };

    let mut report = SimReport { policy: policy.name(), ..Default::default() };

    for layers in trace.iterations.iter() {
        let work = layers.first().map_or(1, |w| w.n_devices() * w.n_experts());
        let outcomes: Vec<LayerOutcome> = match policy {
            Policy::ProProphet(_) => {
                let prophet_ref = prophet.as_ref();
                threads::par_map_mut(&mut planners, work, |l, planner| {
                    plan_and_price(l, &layers[l], policy, &pm, &eng, Some(planner), prophet_ref)
                })
            }
            _ => threads::par_map(n_layers, work, |l| {
                plan_and_price(l, &layers[l], policy, &pm, &eng, None, None)
            }),
        };

        let mut forecast_errs: Vec<f64> = Vec::new();
        if let Some(prophet) = prophet.as_mut() {
            for (l, w) in layers.iter().enumerate() {
                let obs = prophet.observe_layer(l, w);
                if let Some(e) = obs.forecast_error {
                    forecast_errs.push(e);
                }
                if obs.drift {
                    planners[l].invalidate();
                    report.drift_replans += 1;
                }
            }
        }

        let mut costs: Vec<BlockCosts> = Vec::with_capacity(n_layers);
        let mut bal_before = 0.0;
        let mut bal_after = 0.0;
        let mut trans_copies = 0u64;
        for o in outcomes {
            bal_before += o.bal_before;
            bal_after += o.bal_after;
            trans_copies += o.trans_copies;
            costs.push(o.costs);
        }
        bal_before /= n_layers as f64;
        bal_after /= n_layers as f64;

        let schedule = match policy {
            Policy::DeepspeedMoe => build_blocking(&costs, LoadBalanceOps::None),
            Policy::FasterMoe | Policy::TopK(_) => {
                build_blocking(&costs, LoadBalanceOps::Blocking)
            }
            Policy::ProProphet(o) => {
                if o.scheduler_on {
                    build_blockwise(&costs)
                } else {
                    build_blocking(&costs, LoadBalanceOps::Blocking)
                }
            }
        };
        debug_assert!(schedule.validate_dependencies().is_ok());

        let mut per_block = vec![0.0; n_layers];
        for stage in &schedule.stages {
            if let Some(op) = stage.comp.first().or(stage.comm.first()) {
                let b = op.op.block().min(n_layers - 1);
                per_block[b] += stage.time();
            }
        }

        report.iters.push(IterationResult {
            time: schedule.total_time(),
            // The pre-refactor path priced ONLY the barrier model, so the
            // comparison column trivially equals the time.
            barrier_time: schedule.total_time(),
            breakdown: schedule.exposed_breakdown(),
            per_block_time: per_block,
            balance_before: bal_before,
            balance_after: bal_after,
            trans_copies,
            forecast_error: if forecast_errs.is_empty() {
                None
            } else {
                Some(forecast_errs.iter().sum::<f64>() / forecast_errs.len() as f64)
            },
            // The pre-refactor path had no device-level timeline; these
            // post-refactor report fields stay at their neutral values
            // (the golden gate does not compare them).
            des_time: 0.0,
            devices: Vec::new(),
            straggler: 0,
        });
    }

    match policy {
        Policy::ProProphet(_) => {
            report.plans_run = planners.iter().map(|p| p.plans_run).sum();
            report.plans_reused = planners.iter().map(|p| p.plans_reused).sum();
        }
        Policy::FasterMoe => {
            report.plans_run = trace.len() * n_layers;
        }
        Policy::DeepspeedMoe | Policy::TopK(_) => {}
    }
    report
}

/// The pre-refactor `sim::single_layer_times`, preserved bit-for-bit.
pub fn single_layer_times_reference(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    w: &LoadMatrix,
    policy: &Policy,
) -> (f64, f64) {
    let pm = PerfModel::new(model, cluster);
    let eng = Engine::new(cluster, &pm);
    let ident = Placement::identity(w.n_experts(), w.n_devices());
    let t_ident = {
        let costs = [eng.block_costs(w, &ident, 0.0)];
        build_blocking(&costs, LoadBalanceOps::None).total_time()
    };
    let (placement, overlap) = match policy {
        Policy::DeepspeedMoe => (ident, false),
        Policy::FasterMoe => (policies::fastermoe_shadowing(w, &pm), false),
        Policy::TopK(k) => (policies::top_k_to_all(w, *k), false),
        Policy::ProProphet(o) => (
            greedy_search(w, &pm, &o.planner).placement,
            o.scheduler_on,
        ),
    };
    let unicast = matches!(policy, Policy::FasterMoe | Policy::TopK(_));
    let costs = [eng.block_costs_styled(w, &placement, 0.0, unicast)];
    let t_policy = if overlap {
        build_blockwise(&costs).total_time()
    } else {
        build_blocking(&costs, LoadBalanceOps::Blocking).total_time()
    };
    (t_ident, t_policy)
}
