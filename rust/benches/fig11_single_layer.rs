//! Fig 11 — single-layer speedups on MoE-GPT-M over Deepspeed-MoE and
//! FasterMoE for randomly selected layer indices, k in {1, 2}.
//!
//! Paper: 1.60-2.25x vs Deepspeed-MoE, 1.09-1.49x vs FasterMoE per layer.

use pro_prophet::balancer::{registry, ProphetOptions};
use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::sim::single_layer_times_policy;
use pro_prophet::util::json::{self, Json};
use pro_prophet::util::rng::Rng;

fn main() {
    benchkit::header("Fig 11", "single-layer speedups (MoE-GPT-M)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut rng = Rng::new(123);
    let mut all = Vec::new();
    for k in [1usize, 2] {
        let model = ModelSpec::moe_gpt_m(d, k, 16384);
        let trace = scenario::trace_for(&model, d, 2, 5);
        let layers = &trace.iterations[1];
        // Random layer sample, as the paper does.
        let mut idx: Vec<usize> = (0..model.n_layers).collect();
        rng.shuffle(&mut idx);
        idx.truncate(6);
        idx.sort();
        let mut table = TableReport::new(
            &format!("k={k}: single-layer time (ms) and speedups"),
            &["DS (ms)", "FM (ms)", "PP (ms)", "PP/DS", "PP/FM"],
        );
        let opts = ProphetOptions::full();
        let policy = |name: &str| registry::build(name, &opts).expect("registered");
        for &l in &idx {
            let w = &layers[l];
            let (t_ds, _) =
                single_layer_times_policy(&model, &cluster, w, policy("deepspeed"));
            let (_, t_fm) =
                single_layer_times_policy(&model, &cluster, w, policy("fastermoe"));
            let (_, t_pp) =
                single_layer_times_policy(&model, &cluster, w, policy("pro-prophet"));
            table.row(
                &format!("layer {l}"),
                vec![
                    t_ds * 1e3,
                    t_fm * 1e3,
                    t_pp * 1e3,
                    t_ds / t_pp,
                    t_fm / t_pp,
                ],
            );
            all.push(json::obj(vec![
                ("k", json::num(k as f64)),
                ("layer", json::num(l as f64)),
                ("t_deepspeed", json::num(t_ds)),
                ("t_fastermoe", json::num(t_fm)),
                ("t_prophet", json::num(t_pp)),
            ]));
        }
        println!("{}", table.render());
    }
    println!("paper: 1.60-2.25x vs Deepspeed-MoE, 1.09-1.49x vs FasterMoE");
    let path = write_result("fig11_single_layer", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
