//! Fig 10 (a-d) — end-to-end speedups over Deepspeed-MoE and FasterMoE on
//! HPWNV clusters: {16 GPUs/16384 tok, 32 GPUs/32768 tok} x {k=1, k=2} x
//! five MoE-GPT models.
//!
//! Paper: Pro-Prophet 1.36-2.66x vs Deepspeed-MoE, 1.01-1.48x vs FasterMoE.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Fig 10", "end-to-end speedup vs Deepspeed-MoE / FasterMoE (HPWNV)");
    let mut all = Vec::new();
    let mut pp_vs_ds: Vec<f64> = Vec::new();
    let mut pp_vs_fm: Vec<f64> = Vec::new();
    for (panel, nodes, tokens, k) in [
        ("a", 4usize, 16384u64, 1usize),
        ("b", 8, 32768, 1),
        ("c", 4, 16384, 2),
        ("d", 8, 32768, 2),
    ] {
        let cluster = ClusterSpec::hpwnv(nodes);
        let d = cluster.n_devices();
        let mut table = TableReport::new(
            &format!("Fig 10{panel}: {d} GPUs, {tokens} tokens, k={k}"),
            &["FasterMoE", "Pro-Prophet", "PP/FM"],
        );
        for model in ModelSpec::table3(d, k, tokens) {
            let trace = scenario::trace_for(&model, d, 10, 42 + nodes as u64);
            let (ds, fm, pp) = scenario::three_way(&model, &cluster, &trace);
            let s_fm = ds.avg_iter_time() / fm.avg_iter_time();
            let s_pp = ds.avg_iter_time() / pp.avg_iter_time();
            pp_vs_ds.push(s_pp);
            pp_vs_fm.push(fm.avg_iter_time() / pp.avg_iter_time());
            table.row(&model.name, vec![s_fm, s_pp, s_pp / s_fm]);
            all.push(json::obj(vec![
                ("panel", json::s(panel)),
                ("model", json::s(&model.name)),
                ("k", json::num(k as f64)),
                ("gpus", json::num(d as f64)),
                ("speedup_fastermoe", json::num(s_fm)),
                ("speedup_prophet", json::num(s_pp)),
            ]));
        }
        println!("{}", table.render());
    }
    let min_ds = pp_vs_ds.iter().copied().fold(f64::INFINITY, f64::min);
    let max_ds = pp_vs_ds.iter().copied().fold(0.0, f64::max);
    let min_fm = pp_vs_fm.iter().copied().fold(f64::INFINITY, f64::min);
    let max_fm = pp_vs_fm.iter().copied().fold(0.0, f64::max);
    println!("Pro-Prophet vs Deepspeed-MoE: {min_ds:.2}-{max_ds:.2}x  (paper 1.36-2.66x)");
    println!("Pro-Prophet vs FasterMoE:     {min_fm:.2}-{max_fm:.2}x  (paper 1.01-1.48x)");
    let path = write_result("fig10_end_to_end", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
