//! Fig 9 — scheduling strategies for a Trans primitive: whole-op onto the
//! expert computation (a), whole-op onto the non-MoE computation (b), or
//! Pro-Prophet's sub-operator split across both (c).
//!
//! The paper's point: a single computation window often cannot absorb a
//! Trans, so (a)/(b) block the pipeline; the split (c) uses both windows.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, PlannerConfig};
use pro_prophet::scheduler::blockwise::{build_blockwise_mode, SplitMode};
use pro_prophet::sim::Engine;
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Fig 9", "Trans scheduling strategies (sub-operator split ablation)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut out = Vec::new();
    let mut table = TableReport::new(
        "iteration time (ms) per split strategy",
        &["(a) expert-only", "(b) non-MoE-only", "(c) split"],
    );
    for model in ModelSpec::table3(d, 1, 16384) {
        let pm = PerfModel::new(&model, &cluster);
        let eng = Engine::new(&cluster, &pm);
        let trace = scenario::trace_for(&model, d, 2, 9);
        let costs: Vec<_> = trace.iterations[1]
            .iter()
            .map(|w| {
                let p = greedy_search(w, &pm, &PlannerConfig::default()).placement;
                eng.block_costs(w, &p, 0.0)
            })
            .collect();
        let t_a = build_blockwise_mode(&costs, SplitMode::ExpertOnly).total_time();
        let t_b = build_blockwise_mode(&costs, SplitMode::NonExpertOnly).total_time();
        let t_c = build_blockwise_mode(&costs, SplitMode::Split).total_time();
        table.row(&model.name, vec![t_a * 1e3, t_b * 1e3, t_c * 1e3]);
        out.push(json::obj(vec![
            ("model", json::s(&model.name)),
            ("expert_only_s", json::num(t_a)),
            ("non_moe_only_s", json::num(t_b)),
            ("split_s", json::num(t_c)),
        ]));
    }
    println!("{}", table.render());
    println!("paper: the sub-operator split (c) hides Trans that neither single window can absorb");
    let path = write_result("fig9_split", &Json::Arr(out)).unwrap();
    println!("-> {}", path.display());
}
