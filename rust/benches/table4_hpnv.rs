//! Table IV — overall speedup on 4 HPNV nodes (NVLink pairs), 16 GPUs,
//! 16384 tokens, k in {1, 2}, five MoE-GPT models.
//!
//! Paper: Pro-Prophet 1.71-2.63x vs Deepspeed-MoE, 1.10-1.35x vs FasterMoE.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Table IV", "overall speedup on 4 HPNV nodes (NVLink)");
    let cluster = ClusterSpec::hpnv(4);
    let d = cluster.n_devices();
    let mut all = Vec::new();
    for k in [1usize, 2] {
        let mut table = TableReport::new(
            &format!("k={k}, {d} GPUs, 16384 tokens — speedup vs Deepspeed-MoE"),
            &["FasterMoE", "Pro-Prophet"],
        );
        for model in ModelSpec::table3(d, k, 16384) {
            let (s_fm, s_pp) = scenario::speedup_row(&model, &cluster, 10, 77);
            table.row(&model.name, vec![s_fm, s_pp]);
            all.push(json::obj(vec![
                ("k", json::num(k as f64)),
                ("model", json::s(&model.name)),
                ("speedup_fastermoe", json::num(s_fm)),
                ("speedup_prophet", json::num(s_pp)),
            ]));
        }
        println!("{}", table.render());
    }
    println!("paper: Pro-Prophet 1.71-2.63x vs Deepspeed-MoE, 1.10-1.35x vs FasterMoE");
    let path = write_result("table4_hpnv", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
