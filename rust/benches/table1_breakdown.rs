//! Table I — time breakdown of training under a prior systematic
//! load-balancing method (FasterMoE-style): L.B. total plus Search /
//! Place / Reduce shares, for the five Table III models on 16 GPUs.
//!
//! Paper: L.B. 29.2-37.1%, Search 2.6-6.8%, Place 11.6-16.1%,
//! Reduce 11.5-17.7%.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{pct, write_result, TableReport};
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Table I", "load-balancing overhead breakdown (FasterMoE baseline)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut table = TableReport::new(
        "Time breakdown (% of iteration)",
        &["L.B.", "Search", "Place", "Reduce", "Others"],
    );
    let mut results = Vec::new();
    for model in ModelSpec::table3(d, 1, 16384) {
        let trace = scenario::trace_for(&model, d, 12, 42);
        let r = scenario::report_for("fastermoe", &model, &cluster, &trace);
        let search = r.breakdown_fraction("search");
        let place = r.breakdown_fraction("place");
        let reduce = r.breakdown_fraction("reduce");
        let lb = search + place + reduce;
        table.row(
            &model.name,
            vec![pct(lb), pct(search), pct(place), pct(reduce), pct(1.0 - lb)],
        );
        results.push(json::obj(vec![
            ("model", json::s(&model.name)),
            ("lb", json::num(lb)),
            ("search", json::num(search)),
            ("place", json::num(place)),
            ("reduce", json::num(reduce)),
        ]));
    }
    println!("{}", table.render());
    println!("paper band: L.B. 29.2-37.1%  Search 2.6-6.8%  Place 11.6-16.1%  Reduce 11.5-17.7%");
    let path = write_result("table1_breakdown", &Json::Arr(results)).unwrap();
    println!("-> {}", path.display());
}
