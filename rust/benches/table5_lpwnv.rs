//! Table V — overall speedup on 2 LPWNV nodes (2080 Ti), 8 GPUs,
//! 4096 tokens, k in {1, 2}, the four smaller MoE-GPT models.
//!
//! Paper: Pro-Prophet 1.18-1.94x vs Deepspeed-MoE, 1.08-1.50x vs FasterMoE
//! (FasterMoE even loses to Deepspeed-MoE on MoE-GPT-DM k=1: 0.96).

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Table V", "overall speedup on 2 LPWNV nodes (2080 Ti)");
    let cluster = ClusterSpec::lpwnv(2);
    let d = cluster.n_devices();
    let mut all = Vec::new();
    for k in [1usize, 2] {
        let mut table = TableReport::new(
            &format!("k={k}, {d} GPUs, 4096 tokens — speedup vs Deepspeed-MoE"),
            &["FasterMoE", "Pro-Prophet"],
        );
        for model in ModelSpec::table3_small(d, k, 4096) {
            let (s_fm, s_pp) = scenario::speedup_row(&model, &cluster, 10, 99);
            table.row(&model.name, vec![s_fm, s_pp]);
            all.push(json::obj(vec![
                ("k", json::num(k as f64)),
                ("model", json::s(&model.name)),
                ("speedup_fastermoe", json::num(s_fm)),
                ("speedup_prophet", json::num(s_pp)),
            ]));
        }
        println!("{}", table.render());
    }
    println!("paper: Pro-Prophet 1.18-1.94x vs Deepspeed-MoE, 1.08-1.50x vs FasterMoE");
    let path = write_result("table5_lpwnv", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
