//! Fig 3 — the imbalanced load of experts in an iteration: a 12-layer x
//! 16-expert heat map where the three heaviest experts hold >50% of the
//! tokens and the three lightest <5%.

use pro_prophet::benchkit;
use pro_prophet::metrics::write_result;
use pro_prophet::util::json::{self, Json};
use pro_prophet::workload::{top_share, WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header("Fig 3", "per-layer expert load distribution (heat map)");
    let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(12, 16, 16, 16384));
    let layers = gen.next_iteration();

    println!("share of tokens per expert (one row per MoE layer):");
    let mut rows = Vec::new();
    for (l, w) in layers.iter().enumerate() {
        let dist = w.distribution();
        let total: u64 = dist.iter().sum();
        let shares: Vec<f64> = dist.iter().map(|&c| c as f64 / total as f64).collect();
        let cells: String = shares
            .iter()
            .map(|&s| {
                // Poor man's heat map.
                let ch = if s > 0.20 { '#' } else if s > 0.10 { '+' } else if s > 0.05 { '.' } else { ' ' };
                ch
            })
            .collect();
        let top3 = top_share(&dist, 3);
        let mut sorted = dist.clone();
        sorted.sort();
        let bottom3: u64 = sorted.iter().take(3).sum();
        println!(
            "layer {l:>2} |{cells}| top-3 {:>5.1}%  bottom-3 {:>4.1}%",
            100.0 * top3,
            100.0 * bottom3 as f64 / total as f64
        );
        rows.push(json::obj(vec![
            ("layer", json::num(l as f64)),
            ("shares", json::num_arr(&shares)),
            ("top3", json::num(top3)),
        ]));
    }
    let heavy = layers
        .iter()
        .filter(|w| top_share(&w.distribution(), 3) > 0.5)
        .count();
    println!(
        "\n{} of {} layers have top-3 share > 50% (paper: most layers)",
        heavy,
        layers.len()
    );
    let path = write_result("fig3_imbalance", &Json::Arr(rows)).unwrap();
    println!("-> {}", path.display());
}
