//! Fig 14 — effectiveness of components: speedup over the
//! no-optimization baseline when enabling the planner, then the
//! scheduler, then the effective combination (Eq 8-aware planner).
//!
//! Paper (MoE-GPT-M): planner 1.26x/1.12x (k=1/2), scheduler adds
//! 1.14x/1.01x, Full combination adds 1.03x/1.02x.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::balancer::ProphetOptions;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Fig 14", "component ablation (MoE-GPT-M)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut all = Vec::new();
    for k in [1usize, 2] {
        let model = ModelSpec::moe_gpt_m(d, k, 16384);
        let trace = scenario::trace_for(&model, d, 12, 55);
        let base = scenario::report_for("deepspeed", &model, &cluster, &trace);
        let planner = scenario::report_with(
            "pro-prophet",
            &ProphetOptions::planner_only(),
            &model,
            &cluster,
            &trace,
        );
        let scheduler = scenario::report_with(
            "pro-prophet",
            &ProphetOptions::without_combination(),
            &model,
            &cluster,
            &trace,
        );
        let full = scenario::report_for("pro-prophet", &model, &cluster, &trace);
        // PR 5 axis: the same full system with the relaxed-DAG execution
        // mode — barrier waiting removed, identical placements on this
        // homogeneous cluster, so the arm isolates what the stage
        // barriers themselves cost.
        let dag = scenario::report_with(
            "pro-prophet",
            &ProphetOptions::dag(),
            &model,
            &cluster,
            &trace,
        );
        let b = base.avg_iter_time();
        let mut table = TableReport::new(
            &format!("k={k}: speedup over no-optimization baseline"),
            &["speedup", "incremental"],
        );
        let sp = b / planner.avg_iter_time();
        let ss = b / scheduler.avg_iter_time();
        let sf = b / full.avg_iter_time();
        let sd = b / dag.avg_iter_time();
        table.row("+planner", vec![sp, sp]);
        table.row("+scheduler", vec![ss, ss / sp]);
        table.row("Full (combination)", vec![sf, sf / ss]);
        table.row("+relaxed DAG", vec![sd, sd / sf]);
        println!("{}", table.render());
        all.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("planner", json::num(sp)),
            ("scheduler", json::num(ss)),
            ("full", json::num(sf)),
            ("dag_relaxed", json::num(sd)),
        ]));
    }
    println!("paper: planner 1.26x/1.12x, +scheduler 1.14x/1.01x, +Full 1.03x/1.02x");
    let path = write_result("fig14_ablation", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
