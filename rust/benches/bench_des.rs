//! DES throughput: the device-level discrete-event simulator's
//! events/sec and iterations/sec at cluster scale (D = 64, 256, 1024),
//! timed THROUGH the telemetry hub — the same `des.lower`/`des.execute`
//! spans and `des.events` counter the `--metrics` sink records, so the
//! bench doubles as an end-to-end check that hub span timings carry real
//! signal.
//!
//! Results go to the human-readable lines below, bench_results/des.json,
//! and the machine-readable BENCH_des.json at the repo root (uploaded by
//! CI next to BENCH_plan.json; consumed by EXPERIMENTS.md §Perf trend
//! tooling).

use pro_prophet::benchkit;
use pro_prophet::metrics::write_result;
use pro_prophet::obs::{Labels, Recorder, Span, TelemetryHub};
use pro_prophet::scheduler::{
    build_blockwise, build_blockwise_dag, dag, BlockCosts, DeviceBlockCosts,
};
use pro_prophet::sim::events;
use pro_prophet::util::json::{self, Json};

const BLOCKS: usize = 12;

fn block_costs() -> Vec<BlockCosts> {
    vec![
        BlockCosts {
            a2a: 1e-3,
            fec: 2e-3,
            bec: 4e-3,
            fnec: 1e-3,
            bnec: 2e-3,
            trans: 1.5e-3,
            agg: 1.5e-3,
            plan: 3e-4,
        };
        BLOCKS
    ]
}

/// One measured configuration: `reps` lower+execute passes on `d`
/// devices, spans and counters recorded into a fresh hub.
fn measure(d: usize, reps: usize, relaxed: bool) -> Json {
    let costs = block_costs();
    let hub = TelemetryHub::new();
    for i in 0..reps {
        hub.iteration_start(i);
        let op_dag = {
            let _sp = Span::enter(&hub, "des.lower", Labels::None);
            if relaxed {
                let dev: Vec<DeviceBlockCosts> =
                    costs.iter().map(|c| DeviceBlockCosts::uniform(c, d)).collect();
                build_blockwise_dag(&dev, Default::default())
            } else {
                dag::from_schedule(&build_blockwise(&costs), d)
            }
        };
        let des = {
            let _sp = Span::enter(&hub, "des.execute", Labels::None);
            events::execute(&op_dag)
        };
        std::hint::black_box(des.makespan);
        hub.counter("des.events", Labels::None, (op_dag.len() * d) as u64);
        hub.iteration_end();
    }
    let lower = hub.span_agg("des.lower", Labels::None).expect("lower span recorded");
    let execute = hub.span_agg("des.execute", Labels::None).expect("execute span recorded");
    let events = hub.counter_total("des.events", Labels::None);
    let events_per_sec = events as f64 / execute.total.max(1e-12);
    let iters_per_sec = reps as f64 / (lower.total + execute.total).max(1e-12);
    let kind = if relaxed { "relaxed" } else { "barrier" };
    println!(
        "des {kind:<8} D={d:<5} {reps:>3} reps  {events:>9} events  \
         {events_per_sec:>12.0} events/s  {iters_per_sec:>8.1} iters/s  \
         (lower {:.2} ms, execute {:.2} ms per iter)",
        lower.mean() * 1e3,
        execute.mean() * 1e3,
    );
    json::obj(vec![
        ("kind", json::s(kind)),
        ("devices", json::num(d as f64)),
        ("blocks", json::num(BLOCKS as f64)),
        ("reps", json::num(reps as f64)),
        ("events", json::num(events as f64)),
        ("events_per_sec", json::num(events_per_sec)),
        ("iters_per_sec", json::num(iters_per_sec)),
        ("lower_mean_s", json::num(lower.mean())),
        ("execute_mean_s", json::num(execute.mean())),
    ])
}

fn main() {
    benchkit::header("des", "device-level DES events/sec via hub span timings");
    let mut rows: Vec<Json> = Vec::new();
    for (d, reps) in [(64usize, 40usize), (256, 12), (1024, 4)] {
        rows.push(measure(d, reps, false));
        rows.push(measure(d, reps, true));
    }
    let doc = json::obj(vec![
        ("bench", json::s("des")),
        ("unit", json::s("events_per_sec")),
        ("blocks", json::num(BLOCKS as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = write_result("des", &doc).unwrap();
    println!("-> {}", path.display());
    // Machine-readable trajectory seed at the repo root.
    std::fs::write("BENCH_des.json", doc.to_string()).unwrap();
    println!("-> BENCH_des.json");
}
