//! DES throughput: the device-level discrete-event simulator's
//! events/sec and iterations/sec at cluster scale (D = 64, 256, 1024,
//! 4096), timed THROUGH the telemetry hub — the same
//! `des.lower`/`des.execute` spans and `des.events` counter the
//! `--metrics` sink records, so the bench doubles as an end-to-end check
//! that hub span timings carry real signal.
//!
//! Every configuration times BOTH executors over the same DAGs — the
//! arena/scratch hot path (`events::execute_with` with a persistent
//! `ExecScratch`) and the frozen pre-arena oracle
//! (`events::execute_reference`) — and gates the timing on a bitwise
//! equivalence check between them, so the speedup column can never be
//! reported off divergent results.
//!
//! Results go to the human-readable lines below, bench_results/des.json,
//! and the machine-readable BENCH_des.json at the repo root (uploaded by
//! CI next to BENCH_plan.json; consumed by EXPERIMENTS.md §Perf trend
//! tooling).  Set `DES_BENCH_ONLY_D=<devices>` to run a single scale
//! (the CI `des-scale-smoke` job runs only D=4096 under a timeout).

use pro_prophet::benchkit;
use pro_prophet::metrics::write_result;
use pro_prophet::obs::{Labels, Recorder, Span, TelemetryHub};
use pro_prophet::scheduler::{
    build_blockwise, build_blockwise_dag, dag, BlockCosts, DeviceBlockCosts, OpDag,
};
use pro_prophet::sim::events::{self, DesResult, ExecScratch};
use pro_prophet::util::json::{self, Json};

const BLOCKS: usize = 12;

fn block_costs() -> Vec<BlockCosts> {
    vec![
        BlockCosts {
            a2a: 1e-3,
            fec: 2e-3,
            bec: 4e-3,
            fnec: 1e-3,
            bnec: 2e-3,
            trans: 1.5e-3,
            agg: 1.5e-3,
            plan: 3e-4,
        };
        BLOCKS
    ]
}

fn build(d: usize, relaxed: bool) -> OpDag {
    let costs = block_costs();
    if relaxed {
        let dev: Vec<DeviceBlockCosts> =
            costs.iter().map(|c| DeviceBlockCosts::uniform(c, d)).collect();
        build_blockwise_dag(&dev, Default::default())
    } else {
        dag::from_schedule(&build_blockwise(&costs), d)
    }
}

/// Bitwise equivalence gate: the hot path must reproduce the frozen
/// reference exactly (makespan, breakdowns, device stats, straggler)
/// before its timings are allowed into the report.
fn assert_equivalent(hot: &DesResult, reference: &DesResult, what: &str) {
    assert_eq!(
        hot.makespan.to_bits(),
        reference.makespan.to_bits(),
        "{what}: makespan diverged from execute_reference"
    );
    assert_eq!(hot.exposed, reference.exposed, "{what}: exposed breakdown diverged");
    let hot_pb: Vec<u64> = hot.per_block_exposed.iter().map(|v| v.to_bits()).collect();
    let ref_pb: Vec<u64> = reference.per_block_exposed.iter().map(|v| v.to_bits()).collect();
    assert_eq!(hot_pb, ref_pb, "{what}: per-block exposed diverged");
    assert_eq!(hot.devices, reference.devices, "{what}: device stats diverged");
    assert_eq!(hot.straggler, reference.straggler, "{what}: straggler diverged");
}

/// One measured configuration: `reps` lower+execute passes on `d`
/// devices, spans and counters recorded into a fresh hub, the frozen
/// reference executor timed over the same DAGs for the old-vs-new
/// columns.
fn measure(d: usize, reps: usize, relaxed: bool, scratch: &mut ExecScratch) -> Json {
    let kind = if relaxed { "relaxed" } else { "barrier" };
    // Equivalence gate (untimed): hot path == frozen oracle, bitwise.
    {
        let op_dag = build(d, relaxed);
        let hot = events::execute_with(&op_dag, scratch);
        let reference = events::execute_reference(&op_dag);
        assert_equivalent(&hot, &reference, &format!("{kind} D={d}"));
    }

    let hub = TelemetryHub::new();
    for i in 0..reps {
        hub.iteration_start(i);
        let op_dag = {
            let _sp = Span::enter(&hub, "des.lower", Labels::None);
            build(d, relaxed)
        };
        let des = {
            let _sp = Span::enter(&hub, "des.execute", Labels::None);
            events::execute_with(&op_dag, scratch)
        };
        std::hint::black_box(des.makespan);
        let reference = {
            let _sp = Span::enter(&hub, "des.execute_ref", Labels::None);
            events::execute_reference(&op_dag)
        };
        std::hint::black_box(reference.makespan);
        hub.counter("des.events", Labels::None, (op_dag.len() * d) as u64);
        hub.iteration_end();
    }
    let lower = hub.span_agg("des.lower", Labels::None).expect("lower span recorded");
    let execute = hub.span_agg("des.execute", Labels::None).expect("execute span recorded");
    let exec_ref =
        hub.span_agg("des.execute_ref", Labels::None).expect("reference span recorded");
    let events = hub.counter_total("des.events", Labels::None);
    let events_per_sec = events as f64 / execute.total.max(1e-12);
    let events_per_sec_ref = events as f64 / exec_ref.total.max(1e-12);
    let iters_per_sec = reps as f64 / (lower.total + execute.total).max(1e-12);
    let iters_per_sec_ref = reps as f64 / (lower.total + exec_ref.total).max(1e-12);
    let speedup = exec_ref.total / execute.total.max(1e-12);
    println!(
        "des {kind:<8} D={d:<5} {reps:>3} reps  {events:>10} events  \
         new {events_per_sec:>12.0} ev/s  old {events_per_sec_ref:>12.0} ev/s  \
         x{speedup:>5.2}  {iters_per_sec:>8.1} iters/s  \
         (lower {:.2} ms, execute {:.2} ms, reference {:.2} ms per iter)",
        lower.mean() * 1e3,
        execute.mean() * 1e3,
        exec_ref.mean() * 1e3,
    );
    json::obj(vec![
        ("kind", json::s(kind)),
        ("devices", json::num(d as f64)),
        ("blocks", json::num(BLOCKS as f64)),
        ("reps", json::num(reps as f64)),
        ("events", json::num(events as f64)),
        ("events_per_sec", json::num(events_per_sec)),
        ("events_per_sec_ref", json::num(events_per_sec_ref)),
        ("iters_per_sec", json::num(iters_per_sec)),
        ("iters_per_sec_ref", json::num(iters_per_sec_ref)),
        ("execute_speedup", json::num(speedup)),
        ("lower_mean_s", json::num(lower.mean())),
        ("execute_mean_s", json::num(execute.mean())),
        ("execute_ref_mean_s", json::num(exec_ref.mean())),
    ])
}

fn main() {
    benchkit::header("des", "device-level DES events/sec via hub span timings (old vs new)");
    let only_d: Option<usize> = std::env::var("DES_BENCH_ONLY_D")
        .ok()
        .map(|s| s.parse().expect("DES_BENCH_ONLY_D expects a device count"));
    let mut rows: Vec<Json> = Vec::new();
    // One scratch across every configuration: the bench exercises the
    // same reuse pattern the simulator's PriceState does.
    let mut scratch = ExecScratch::new();
    for (d, reps) in [(64usize, 40usize), (256, 12), (1024, 4), (4096, 2)] {
        if only_d.is_some_and(|only| only != d) {
            continue;
        }
        rows.push(measure(d, reps, false, &mut scratch));
        rows.push(measure(d, reps, true, &mut scratch));
    }
    assert!(!rows.is_empty(), "DES_BENCH_ONLY_D matched no configured scale");
    let doc = json::obj(vec![
        ("bench", json::s("des")),
        ("unit", json::s("events_per_sec")),
        ("blocks", json::num(BLOCKS as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = write_result("des", &doc).unwrap();
    println!("-> {}", path.display());
    // Machine-readable trajectory seed at the repo root.
    std::fs::write("BENCH_des.json", doc.to_string()).unwrap();
    println!("-> BENCH_des.json");
}
