//! Fleet throughput: whole coordinator ticks/sec — admission, per-tenant
//! trace generation, DES pricing over each leased sub-cluster, and the
//! rebalancer — at 1, 4 and 16 concurrent tenants sharing HPWNV-16 (64
//! devices).  The tenant count sweeps the leasing axis while the device
//! total stays fixed, so the numbers separate coordinator overhead from
//! pricing cost (16 tenants price sixteen 4-device DES runs per tick;
//! one tenant prices a single 64-device run).
//!
//! Results go to the human-readable lines below, bench_results/fleet.json,
//! and the machine-readable BENCH_fleet.json at the repo root (uploaded
//! by CI next to BENCH_des.json).

use pro_prophet::balancer::ProphetOptions;
use pro_prophet::benchkit;
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::faults::FaultTimeline;
use pro_prophet::fleet::{AdmissionPolicy, Fleet, FleetConfig, JobSpec};
use pro_prophet::metrics::write_result;
use pro_prophet::obs;
use pro_prophet::util::json::{self, Json};

const TICKS: usize = 8;

/// `jobs` training tenants splitting the 16 nodes evenly, every tenant
/// busy for the whole horizon (iters > ticks: nobody completes, the
/// steady-state cost is what gets timed).
fn config(jobs: usize) -> FleetConfig {
    let nodes_each = 16 / jobs;
    let specs: Vec<JobSpec> = (0..jobs)
        .map(|i| {
            JobSpec::parse(&format!(
                "train name=j{i} nodes={nodes_each} model=s tokens=8192 \
                 iters={} policy=pro-prophet seed={}",
                TICKS + 1,
                11 + i as u64,
            ))
            .expect("bench job spec must parse")
        })
        .collect();
    FleetConfig {
        ticks: TICKS,
        tick_s: 0.25,
        max_concurrent: jobs,
        admission: AdmissionPolicy::Fifo,
        rebalance_interval: 4,
        migration_budget: 1,
        jobs: specs,
    }
}

fn measure(jobs: usize, cluster: &ClusterSpec) -> Json {
    let cfg = config(jobs);
    let popts = ProphetOptions::default();
    let faults = FaultTimeline::empty();
    // Warm-up run outside the clock (trace capture allocs, first plans).
    let warm = Fleet::run(&cfg, cluster, &popts, &faults, obs::noop_arc())
        .expect("bench fleet must run");
    assert_eq!(warm.jobs.len(), jobs);

    let start = std::time::Instant::now();
    let report = Fleet::run(&cfg, cluster, &popts, &faults, obs::noop_arc())
        .expect("bench fleet must run");
    let elapsed = start.elapsed().as_secs_f64().max(1e-12);
    std::hint::black_box(&report);

    let tenant_iters: usize = report.jobs.iter().map(|j| j.iterations).sum();
    let ticks_per_sec = TICKS as f64 / elapsed;
    let iters_per_sec = tenant_iters as f64 / elapsed;
    println!(
        "fleet jobs={jobs:<3} nodes/tenant={:<3} {TICKS} ticks  \
         {ticks_per_sec:>8.1} ticks/s  {iters_per_sec:>8.1} tenant-iters/s  \
         ({:.2} ms/tick)",
        16 / jobs,
        elapsed / TICKS as f64 * 1e3,
    );
    json::obj(vec![
        ("jobs", json::num(jobs as f64)),
        ("nodes_per_tenant", json::num((16 / jobs) as f64)),
        ("ticks", json::num(TICKS as f64)),
        ("tenant_iters", json::num(tenant_iters as f64)),
        ("ticks_per_sec", json::num(ticks_per_sec)),
        ("tenant_iters_per_sec", json::num(iters_per_sec)),
        ("utilization", json::num(report.utilization())),
    ])
}

fn main() {
    benchkit::header("fleet", "multi-tenant coordinator ticks/sec on HPWNV-16");
    let cluster = ClusterSpec::hpwnv(16);
    let mut rows: Vec<Json> = Vec::new();
    for jobs in [1usize, 4, 16] {
        rows.push(measure(jobs, &cluster));
    }
    let doc = json::obj(vec![
        ("bench", json::s("fleet")),
        ("unit", json::s("ticks_per_sec")),
        ("devices", json::num(cluster.n_devices() as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = write_result("fleet", &doc).unwrap();
    println!("-> {}", path.display());
    // Machine-readable trajectory seed at the repo root.
    std::fs::write("BENCH_fleet.json", doc.to_string()).unwrap();
    println!("-> BENCH_fleet.json");
}
