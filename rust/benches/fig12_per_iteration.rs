//! Fig 12 — per-iteration execution time on MoE-GPT-M (k=1) over 100
//! iterations: Pro-Prophet's line sits consistently below FasterMoE's and
//! is visibly less jittery.
//!
//! Paper: 1.34x average speedup over FasterMoE.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::write_result;
use pro_prophet::util::json;
use pro_prophet::util::stats;

fn main() {
    benchkit::header("Fig 12", "per-iteration execution time (MoE-GPT-M, k=1)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let model = ModelSpec::moe_gpt_m(d, 1, 16384);
    let trace = scenario::trace_for(&model, d, 100, 2026);
    let fm = scenario::report_for("fastermoe", &model, &cluster, &trace);
    let pp = scenario::report_for("pro-prophet", &model, &cluster, &trace);
    let fm_t = fm.iter_times();
    let pp_t = pp.iter_times();

    println!("iteration time (s), every 10th iteration:");
    println!("{:>6} {:>12} {:>12}", "iter", "FasterMoE", "Pro-Prophet");
    for i in (0..fm_t.len()).step_by(10) {
        println!("{:>6} {:>12.4} {:>12.4}", i, fm_t[i], pp_t[i]);
    }
    let speedups: Vec<f64> = fm_t.iter().zip(&pp_t).map(|(a, b)| a / b).collect();
    println!(
        "\nmean speedup over FasterMoE: {:.2}x (paper: 1.34x avg)",
        stats::mean(&speedups)
    );
    println!(
        "jitter (std/mean): FasterMoE {:.3}, Pro-Prophet {:.3} (paper: PP is consistent)",
        stats::cv(&fm_t),
        stats::cv(&pp_t)
    );
    let out = json::obj(vec![
        ("fastermoe", json::num_arr(&fm_t)),
        ("prophet", json::num_arr(&pp_t)),
        ("mean_speedup", json::num(stats::mean(&speedups))),
    ]);
    let path = write_result("fig12_per_iteration", &out).unwrap();
    println!("-> {}", path.display());
}
