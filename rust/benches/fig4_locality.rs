//! Fig 4 — locality of input distributions: adjacent iterations of a MoE
//! layer route tokens almost identically (the property Pro-Prophet's
//! planner and scheduler are built on).

use pro_prophet::benchkit;
use pro_prophet::metrics::write_result;
use pro_prophet::planner::locality::{correlation, similarity};
use pro_prophet::util::json;
use pro_prophet::util::stats;
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header("Fig 4", "locality of input distributions across iterations");
    // Layer 2 of a 12-layer model, as in the paper.
    let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(12, 16, 16, 16384));
    let iters = 50;
    let mut dists = Vec::new();
    for _ in 0..iters {
        dists.push(gen.next_iteration()[2].distribution());
    }

    let mut sims = Vec::new();
    let mut corrs = Vec::new();
    for w in dists.windows(2) {
        sims.push(similarity(&w[0], &w[1]));
        corrs.push(correlation(&w[0], &w[1]));
    }
    println!("adjacent-iteration similarity (1 - L1/2): ");
    println!(
        "  mean {:.4}  min {:.4}  p5 {:.4}",
        stats::mean(&sims),
        stats::min(&sims),
        stats::percentile(&sims, 5.0)
    );
    println!(
        "adjacent-iteration Pearson correlation: mean {:.4}  min {:.4}",
        stats::mean(&corrs),
        stats::min(&corrs)
    );

    // Stacked-area style dump of the heaviest 5 experts over time.
    let total: u64 = dists[0].iter().sum();
    let mut order: Vec<usize> = (0..16).collect();
    order.sort_by_key(|&e| std::cmp::Reverse(dists[0][e]));
    println!("\nshare over iterations (heaviest 5 experts at iter 0):");
    for &e in order.iter().take(5) {
        let series: Vec<f64> = dists
            .iter()
            .map(|d| d[e] as f64 / total as f64)
            .collect();
        let spark: String = series
            .iter()
            .step_by(2)
            .map(|&s| match (s * 40.0) as u32 {
                0 => ' ',
                1..=2 => '.',
                3..=5 => '+',
                6..=9 => '*',
                _ => '#',
            })
            .collect();
        println!(
            "  expert {e:>2} |{spark}| {:.3} -> {:.3}",
            series[0],
            series[series.len() - 1]
        );
    }

    let out = json::obj(vec![
        ("similarity", json::num_arr(&sims)),
        ("correlation", json::num_arr(&corrs)),
        ("mean_similarity", json::num(stats::mean(&sims))),
    ]);
    let path = write_result("fig4_locality", &out).unwrap();
    println!("\npaper: distributions of adjacent iterations remain relatively constant");
    println!("-> {}", path.display());
}
