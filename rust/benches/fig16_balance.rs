//! Fig 16 — balance capability: ratio of the planner's RB (balance-degree
//! improvement) to FasterMoE's, per layer, k in {1, 2}.
//!
//! Paper: ratios up to 11.01x, with a few layers below 1 (the planner
//! deliberately places fewer replicas when the load does not warrant it).

use pro_prophet::benchkit;
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{balance_degree, write_result, TableReport};
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, policies, PlannerConfig};
use pro_prophet::util::json::{self, Json};
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header("Fig 16", "RB ratio: planner vs FasterMoE, per layer");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut all = Vec::new();
    for k in [1usize, 2] {
        let model = ModelSpec::moe_gpt_m(d, k, 16384);
        let pm = PerfModel::new(&model, &cluster);
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(
            8,
            d,
            d,
            16384 * k as u64,
        ));
        gen.next_iteration(); // warm one iteration
        let layers = gen.next_iteration();
        let mut table = TableReport::new(
            &format!("k={k}: RB (before/after balance degree)"),
            &["RB planner", "RB FasterMoE", "ratio"],
        );
        let mut max_ratio: f64 = 0.0;
        for (l, w) in layers.iter().enumerate() {
            let before = balance_degree(&w.route_identity().h);
            let p_pp = greedy_search(w, &pm, &PlannerConfig::default()).placement;
            let p_fm = policies::fastermoe_shadowing(w, &pm);
            let rb_pp = before / balance_degree(&w.route(&p_pp).h).max(1e-9);
            let rb_fm = before / balance_degree(&w.route(&p_fm).h).max(1e-9);
            let ratio = rb_pp / rb_fm;
            max_ratio = max_ratio.max(ratio);
            table.row(&format!("layer {l}"), vec![rb_pp, rb_fm, ratio]);
            all.push(json::obj(vec![
                ("k", json::num(k as f64)),
                ("layer", json::num(l as f64)),
                ("rb_planner", json::num(rb_pp)),
                ("rb_fastermoe", json::num(rb_fm)),
                ("ratio", json::num(ratio)),
            ]));
        }
        println!("{}", table.render());
        println!("k={k}: max RB ratio {max_ratio:.2}x (paper: up to 11.01x)\n");
    }
    let path = write_result("fig16_balance", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
