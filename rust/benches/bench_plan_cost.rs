//! Plan-primitive throughput: old (full re-route per candidate) vs new
//! (incremental RoutingState) greedy search, in plans/sec.
//!
//! The paper's premise is that Plan is cheap enough to run online every
//! iteration (Table I "Search": low milliseconds); this bench tracks that
//! cost across cluster scales and seeds the repo's perf trajectory.
//! Results go to the human-readable table below, bench_results/
//! plan_cost.json, and the machine-readable BENCH_plan.json at the repo
//! root (consumed by EXPERIMENTS.md §Perf and CI trend tooling).
//!
//! Every combo is equivalence-gated before timing: the incremental search
//! must return the same placement and bit-identical t_est as the
//! reference implementation.

use pro_prophet::benchkit::{self, bench_fn};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::write_result;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{
    greedy_search_reference, greedy_search_with, PlannerConfig, SearchScratch,
};
use pro_prophet::util::json::{self, Json};
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header("plan_cost", "greedy-search plans/sec, old vs incremental");
    // The acceptance scenario plans EVERY iteration (replan_interval = 1);
    // the interval only gates how often Planner calls the search, so the
    // per-search cost measured here IS the per-iteration planning cost.
    let cfg = PlannerConfig { replan_interval: 1, ..Default::default() };
    let mut rows: Vec<Json> = Vec::new();

    for (d, e) in [(8usize, 8usize), (16, 32), (64, 64), (128, 256)] {
        let tokens = 1024 * d as u64;
        let model = ModelSpec::moe_gpt_m(e, 1, tokens);
        let cluster = ClusterSpec::hpwnv(d.div_ceil(4));
        assert_eq!(cluster.n_devices(), d);
        let pm = PerfModel::new(&model, &cluster);
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(1, e, d, tokens));
        let w = gen.next_iteration().pop().unwrap();

        // Equivalence gate before timing anything.
        let mut scratch = SearchScratch::new();
        let new = greedy_search_with(&w, &pm, &cfg, &mut scratch);
        let old = greedy_search_reference(&w, &pm, &cfg);
        assert_eq!(new.placement, old.placement, "D={d} E={e}: placements diverged");
        assert_eq!(
            new.t_est.to_bits(),
            old.t_est.to_bits(),
            "D={d} E={e}: t_est diverged"
        );

        let r_old = bench_fn(&format!("greedy old D={d} E={e}"), 250.0, || {
            std::hint::black_box(greedy_search_reference(&w, &pm, &cfg));
        });
        println!("{}", r_old.line());
        let r_new = bench_fn(&format!("greedy new D={d} E={e}"), 250.0, || {
            std::hint::black_box(greedy_search_with(&w, &pm, &cfg, &mut scratch));
        });
        println!("{}", r_new.line());

        let pps_old = 1.0 / r_old.mean_s.max(1e-12);
        let pps_new = 1.0 / r_new.mean_s.max(1e-12);
        let speedup = pps_new / pps_old.max(1e-12);
        println!(
            "  -> D={d:<3} E={e:<3}  {pps_old:>10.0} -> {pps_new:>10.0} plans/s  ({speedup:.2}x)\n"
        );
        rows.push(json::obj(vec![
            ("devices", json::num(d as f64)),
            ("experts", json::num(e as f64)),
            ("plans_per_sec_old", json::num(pps_old)),
            ("plans_per_sec_new", json::num(pps_new)),
            ("speedup", json::num(speedup)),
            ("mean_s_old", json::num(r_old.mean_s)),
            ("mean_s_new", json::num(r_new.mean_s)),
            ("experts_selected", json::num(new.selected.len() as f64)),
            ("candidates_evaluated", json::num(new.evaluated as f64)),
        ]));
    }

    let doc = json::obj(vec![
        ("bench", json::s("plan_cost")),
        ("unit", json::s("plans_per_sec")),
        ("replan_interval", json::num(1.0)),
        ("results", Json::Arr(rows)),
    ]);
    let path = write_result("plan_cost", &doc).unwrap();
    println!("-> {}", path.display());
    // Machine-readable trajectory seed at the repo root.
    std::fs::write("BENCH_plan.json", doc.to_string()).unwrap();
    println!("-> BENCH_plan.json");
}
