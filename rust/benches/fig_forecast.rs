//! fig_forecast — the prophet subsystem's headline trade-off: one-step
//! forecast error vs replan count vs simulated iteration time, per
//! predictor, across workload regimes.
//!
//! Planning runs with a lazy replan interval (8) so forecast quality and
//! drift detection are what decide whether stale placements hurt: a good
//! forecaster keeps iteration time low with FEW plans; a bad one either
//! eats drift-forced replans (search time) or mis-balanced iterations.

use pro_prophet::balancer::ProphetOptions;
use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::planner::PlannerConfig;
use pro_prophet::prophet::{PredictorKind, ProphetConfig};
use pro_prophet::util::json::{self, Json};
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header(
        "Fig F",
        "prophet forecasting: error vs replan count vs iteration time",
    );
    let model = ModelSpec::moe_gpt_s(16, 1, 16384);
    let cluster = ClusterSpec::hpwnv(4);
    let iters = 40;
    // Three workload regimes: near-frozen popularity, the paper's Fig 4
    // locality, and a fast-drifting distribution that punishes staleness.
    let scenarios: [(&str, f64); 3] = [("stable", 0.01), ("paper", 0.05), ("shifting", 0.25)];
    let replan_interval = 8;

    let kinds = [
        PredictorKind::Auto,
        PredictorKind::LastValue,
        PredictorKind::Ema,
        PredictorKind::WindowMean,
        PredictorKind::LinearTrend,
    ];

    let mut out = Vec::new();
    for (name, drift) in scenarios {
        let mut wcfg = WorkloadConfig::paper_default(
            model.n_layers,
            model.n_experts,
            cluster.n_devices(),
            model.tokens_per_iter,
        );
        wcfg.drift = drift;
        wcfg.seed = 7;
        let trace = Trace::capture(&mut WorkloadGen::new(wcfg), iters);

        let mut table = TableReport::new(
            &format!(
                "{name} (drift {drift}): {iters} iters, replan interval {replan_interval}"
            ),
            &["fcast_l1", "plans", "drift", "iter_s"],
        );
        let mut rows = Vec::new();
        for kind in kinds {
            let opts = ProphetOptions {
                planner: PlannerConfig {
                    replan_interval,
                    ..Default::default()
                },
                prophet: ProphetConfig { predictor: kind, ..Default::default() },
                ..Default::default()
            };
            let r = scenario::report_with("pro-prophet", &opts, &model, &cluster, &trace);
            let fcast = r.mean_forecast_error();
            table.row(
                kind.name(),
                vec![
                    fcast,
                    r.plans_run as f64,
                    r.drift_replans as f64,
                    r.avg_iter_time(),
                ],
            );
            rows.push(json::obj(vec![
                ("predictor", json::s(kind.name())),
                ("forecast_l1", json::num(fcast)),
                ("plans_run", json::num(r.plans_run as f64)),
                ("drift_replans", json::num(r.drift_replans as f64)),
                ("avg_iter_s", json::num(r.avg_iter_time())),
            ]));
        }
        println!("{}", table.render());
        out.push(json::obj(vec![
            ("scenario", json::s(name)),
            ("drift", json::num(drift)),
            ("iters", json::num(iters as f64)),
            ("replan_interval", json::num(replan_interval as f64)),
            ("rows", Json::Arr(rows)),
        ]));
    }

    let path = write_result("fig_forecast", &Json::Arr(out)).unwrap();
    println!("takeaway: on local workloads every predictor keeps error low and");
    println!("plans rare; as drift grows, the adaptive ensemble tracks the best");
    println!("member and drift detection converts forecast misses into replans.");
    println!("-> {}", path.display());
}
