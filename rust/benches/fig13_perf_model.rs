//! Fig 13 — accuracy of the performance model: estimated vs "real"
//! (discrete-event engine) time for A2A, expert computation (EC), Trans
//! and Agg, over many sampled workloads.
//!
//! Paper: mean estimation error < 5%.

use pro_prophet::benchkit;
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, PlannerConfig};
use pro_prophet::sim::Engine;
use pro_prophet::util::json::{self, Json};
use pro_prophet::util::stats;
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header("Fig 13", "performance model accuracy (estimate vs engine)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let model = ModelSpec::moe_gpt_m(d, 1, 16384);
    let pm = PerfModel::new(&model, &cluster);
    let eng = Engine::new(&cluster, &pm);
    let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(8, d, d, 16384));

    let mut est = vec![Vec::new(); 4]; // a2a, ec, trans, agg
    let mut real = vec![Vec::new(); 4];
    for _ in 0..6 {
        for w in gen.next_iteration() {
            let p = greedy_search(&w, &pm, &PlannerConfig::default()).placement;
            let routed = w.route(&p);
            // A2A
            est[0].push(pm.t_a2a(&routed.r));
            real[0].push(eng.a2a_time(&w.traffic(&p)));
            // EC (forward)
            est[1].push(pm.t_fec(&routed.h));
            real[1].push(eng.fec_time(&routed.h));
            // Trans / Agg (skip identity placements: both sides are 0)
            if !p.is_identity() {
                est[2].push(pm.t_trans(&p));
                real[2].push(eng.trans_time(&p));
                est[3].push(pm.t_agg(&p));
                real[3].push(eng.agg_time(&p));
            }
        }
    }

    let names = ["A2A", "EC", "Trans", "Agg"];
    let mut table = TableReport::new(
        "mean |estimate - real| / real (%)",
        &["mean err %", "samples"],
    );
    let mut out = Vec::new();
    let mut errs_all = Vec::new();
    for i in 0..4 {
        let err = stats::mape(&est[i], &real[i]);
        errs_all.push(err);
        table.row(names[i], vec![100.0 * err, est[i].len() as f64]);
        out.push(json::obj(vec![
            ("op", json::s(names[i])),
            ("mape", json::num(err)),
            ("estimates", json::num_arr(&est[i])),
            ("measured", json::num_arr(&real[i])),
        ]));
    }
    println!("{}", table.render());
    let overall = stats::mean(&errs_all);
    println!(
        "overall mean estimation error: {:.2}% (paper: < 5%)",
        100.0 * overall
    );
    let path = write_result("fig13_perf_model", &Json::Arr(out)).unwrap();
    println!("-> {}", path.display());
}
