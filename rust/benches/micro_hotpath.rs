//! Micro-benchmarks of the L3 hot paths (the §Perf deliverable):
//! greedy search latency, routing/traffic computation, engine pricing,
//! schedule construction, the device-level event timeline, and a whole
//! simulated iteration.
//!
//! These numbers feed EXPERIMENTS.md §Perf; the planner search must stay
//! well under the A2A it hides beneath (hundreds of µs at most), and the
//! per-iteration DES pass (barrier lowering + execute) must stay a small
//! fraction of the schedule-construction budget it rides on.

use pro_prophet::benchkit::{self, bench_fn, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::write_result;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, PlannerConfig};
use pro_prophet::scheduler::{build_blockwise, build_blockwise_dag, dag, BlockCosts, DeviceBlockCosts};
use pro_prophet::sim::{events, Engine};
use pro_prophet::util::json::{self, Json};
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};

fn main() {
    benchkit::header("micro", "L3 hot-path microbenchmarks");
    let mut results = Vec::new();
    let mut record = |r: pro_prophet::benchkit::BenchResult| {
        println!("{}", r.line());
        results.push(json::obj(vec![
            ("name", json::s(&r.name)),
            ("mean_s", json::num(r.mean_s)),
            ("std_s", json::num(r.std_s)),
            ("iters", json::num(r.iters as f64)),
        ]));
    };

    for d in [8usize, 16, 32] {
        let model = ModelSpec::moe_gpt_m(d, 1, 16384);
        let cluster = ClusterSpec::hpwnv(d / 4);
        let pm = PerfModel::new(&model, &cluster);
        let eng = Engine::new(&cluster, &pm);
        let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(1, d, d, 16384));
        let w = gen.next_iteration().pop().unwrap();
        let cfg = PlannerConfig::default();

        record(bench_fn(&format!("greedy_search D={d}"), 60.0, || {
            std::hint::black_box(greedy_search(&w, &pm, &cfg));
        }));
        let placement = greedy_search(&w, &pm, &cfg).placement;
        record(bench_fn(&format!("route D={d}"), 30.0, || {
            std::hint::black_box(w.route(&placement));
        }));
        record(bench_fn(&format!("traffic_matrix D={d}"), 30.0, || {
            std::hint::black_box(w.traffic(&placement));
        }));
        record(bench_fn(&format!("engine_block_costs D={d}"), 30.0, || {
            std::hint::black_box(eng.block_costs(&w, &placement, 0.0));
        }));
    }

    // Schedule construction over 24 blocks.
    let costs = vec![
        BlockCosts {
            a2a: 1e-3,
            fec: 2e-3,
            bec: 4e-3,
            fnec: 1e-3,
            bnec: 2e-3,
            trans: 1.5e-3,
            agg: 1.5e-3,
            plan: 3e-4,
        };
        24
    ];
    record(bench_fn("build_blockwise 24 blocks", 30.0, || {
        std::hint::black_box(build_blockwise(&costs));
    }));

    // Device-level event timeline: lower the 24-block schedule to a
    // barrier DAG on 16 devices and execute it (this pass now runs once
    // per simulated iteration), plus the relaxed Algorithm-2 DAG.
    let sched24 = build_blockwise(&costs);
    record(bench_fn("dag build (from_schedule) 24 blocks x 16 dev", 30.0, || {
        std::hint::black_box(dag::from_schedule(&sched24, 16));
    }));
    record(bench_fn("dag lower+execute 24 blocks x 16 dev", 30.0, || {
        let lowered = dag::from_schedule(&sched24, 16);
        std::hint::black_box(events::execute(&lowered));
    }));
    let dev_costs: Vec<DeviceBlockCosts> =
        costs.iter().map(|c| DeviceBlockCosts::uniform(c, 16)).collect();
    record(bench_fn("blockwise_dag build 24 blocks x 16 dev", 30.0, || {
        std::hint::black_box(build_blockwise_dag(&dev_costs, Default::default()));
    }));
    record(bench_fn("blockwise_dag execute 24 blocks x 16 dev", 30.0, || {
        let relaxed = build_blockwise_dag(&dev_costs, Default::default());
        std::hint::black_box(events::execute(&relaxed));
    }));
    // Scratch reuse: the simulator's steady-state execute (buffers
    // carried across iterations, no per-call allocation, times not
    // retained) vs the allocating `events::execute` above.
    let lowered16 = dag::from_schedule(&sched24, 16);
    let mut scratch = events::ExecScratch::new();
    record(bench_fn("execute scratch-reuse 24 blocks x 16 dev", 30.0, || {
        std::hint::black_box(events::execute_with(&lowered16, &mut scratch).makespan);
    }));
    // The planner's whole-iteration relaxed estimate must stay much
    // cheaper than executing the DAG it bounds.
    record(bench_fn("relaxed_makespan_bound 24 blocks x 16 dev", 30.0, || {
        std::hint::black_box(pro_prophet::scheduler::relaxed_makespan_bound(
            &dev_costs,
            Default::default(),
        ));
    }));

    // Whole simulated iteration (12-layer model, 16 devices).
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let cluster = ClusterSpec::hpwnv(4);
    let trace = Trace::capture(
        &mut WorkloadGen::new(WorkloadConfig::paper_default(12, 16, 16, 16384)),
        1,
    );
    record(bench_fn("simulate 1 iter x 12 layers (prophet)", 120.0, || {
        std::hint::black_box(scenario::report_for("pro-prophet", &model, &cluster, &trace));
    }));
    record(bench_fn("simulate 1 iter x 12 layers (prophet-dag)", 120.0, || {
        std::hint::black_box(scenario::report_for(
            "pro-prophet-dag",
            &model,
            &cluster,
            &trace,
        ));
    }));

    // Telemetry overhead: a disarmed span through the no-op recorder
    // must be ~free (no Instant::now, no allocation) — this is the "no
    // measurable recorder overhead with telemetry off" guarantee — and
    // even the live hub path stays far below the work it wraps.
    {
        use pro_prophet::obs::{self, Labels, Recorder, Span, TelemetryHub};
        record(bench_fn("span noop (telemetry off)", 30.0, || {
            let sp = Span::enter(obs::noop(), "bench.span", Labels::None);
            std::hint::black_box(&sp);
        }));
        let hub = TelemetryHub::new();
        hub.iteration_start(0);
        record(bench_fn("span hub (telemetry on)", 30.0, || {
            let sp = Span::enter(&hub, "bench.span", Labels::None);
            std::hint::black_box(&sp);
        }));
        hub.iteration_end();
    }

    let path = write_result("micro_hotpath", &Json::Arr(results)).unwrap();
    println!("-> {}", path.display());
}
