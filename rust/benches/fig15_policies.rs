//! Fig 15 — iteration latency of the planner vs the simple dynamic
//! policies top2/top3 (replicate the 2/3 heaviest experts to all GPUs),
//! MoE-GPT-M, k in {1, 2}.
//!
//! Paper: planner 1.77-1.82x faster than top2 and 2.04-2.10x than top3 at
//! k=1; 1.38-1.40x at k=2.

use pro_prophet::benchkit::{self, scenario};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::{write_result, TableReport};
use pro_prophet::util::json::{self, Json};

fn main() {
    benchkit::header("Fig 15", "planner vs static top-k policies (MoE-GPT-M)");
    let cluster = ClusterSpec::hpwnv(4);
    let d = cluster.n_devices();
    let mut all = Vec::new();
    for k in [1usize, 2] {
        let model = ModelSpec::moe_gpt_m(d, k, 16384);
        let trace = scenario::trace_for(&model, d, 12, 66);
        // Planner without the scheduler, matching the paper's policy-level
        // comparison.
        let planner = scenario::report_for("planner-only", &model, &cluster, &trace);
        let top2 = scenario::report_for("top2", &model, &cluster, &trace);
        let top3 = scenario::report_for("top3", &model, &cluster, &trace);
        let mut table = TableReport::new(
            &format!("k={k}: iteration latency (s)"),
            &["latency_s", "planner_speedup"],
        );
        let p = planner.avg_iter_time();
        table.row("planner", vec![p, 1.0]);
        table.row("top2", vec![top2.avg_iter_time(), top2.avg_iter_time() / p]);
        table.row("top3", vec![top3.avg_iter_time(), top3.avg_iter_time() / p]);
        println!("{}", table.render());
        all.push(json::obj(vec![
            ("k", json::num(k as f64)),
            ("planner_s", json::num(p)),
            ("top2_s", json::num(top2.avg_iter_time())),
            ("top3_s", json::num(top3.avg_iter_time())),
        ]));
    }
    println!("paper: planner 1.77-1.82x vs top2, 2.04-2.10x vs top3 (k=1); 1.38-1.40x (k=2)");
    let path = write_result("fig15_policies", &Json::Arr(all)).unwrap();
    println!("-> {}", path.display());
}
