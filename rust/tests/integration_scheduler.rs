//! Integration: scheduler over engine-priced real workloads — verifies the
//! §V overlap claims against whole-iteration timelines.

use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, PlannerConfig};
use pro_prophet::scheduler::{build_blocking, build_blockwise, BlockCosts, LoadBalanceOps};
use pro_prophet::sim::Engine;
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn real_costs(n_layers: usize) -> Vec<BlockCosts> {
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let cluster = ClusterSpec::hpwnv(4);
    let pm = PerfModel::new(&model, &cluster);
    let eng = Engine::new(&cluster, &pm);
    let mut gen =
        WorkloadGen::new(WorkloadConfig::paper_default(n_layers, 16, 16, 16384));
    gen.next_iteration()
        .iter()
        .map(|w| {
            let p = greedy_search(w, &pm, &PlannerConfig::default()).placement;
            eng.block_costs(w, &p, pm.t_plan)
        })
        .collect()
}

#[test]
fn blockwise_schedule_beats_blocking_on_real_workload() {
    let costs = real_costs(12);
    let blocking = build_blocking(&costs, LoadBalanceOps::Blocking);
    let overlapped = build_blockwise(&costs);
    assert!(overlapped.total_time() < blocking.total_time());
    overlapped.validate_dependencies().unwrap();
    blocking.validate_dependencies().unwrap();
}

#[test]
fn overlap_respects_compute_lower_bound() {
    // Overlap can hide comm under comp, never shrink comp itself.
    let costs = real_costs(12);
    let lower: f64 = costs
        .iter()
        .map(|c| c.fec + c.bec + c.fnec + c.bnec)
        .sum();
    let sched = build_blockwise(&costs);
    assert!(sched.total_time() >= lower);
}

#[test]
fn lb_ops_mostly_hidden_in_blockwise() {
    let costs = real_costs(12);
    let blocking = build_blocking(&costs, LoadBalanceOps::Blocking);
    let overlapped = build_blockwise(&costs);
    let lb_blocking = blocking.lb_fraction();
    let lb_overlapped = overlapped.lb_fraction();
    // The blockwise schedule hides a large share of Plan/Trans/Agg; what
    // remains exposed is block 0's edges plus overflow beyond the comp
    // windows (these costs charge Plan on every block, which the locality
    // cache amortizes further in the full system).
    assert!(
        lb_overlapped < 0.75 * lb_blocking,
        "scheduler should hide much of the LB overhead: {lb_overlapped} vs {lb_blocking}"
    );
}

#[test]
fn table1_magnitude_for_blocking_lb() {
    // Paper Table I: blocking systematic LB burns ~30-37% of iteration
    // time; our blocking schedule over real costs should land in a
    // comparable band (wide tolerance — it depends on skew).
    let costs = real_costs(12);
    let blocking = build_blocking(&costs, LoadBalanceOps::Blocking);
    let lb = blocking.lb_fraction();
    assert!(
        (0.05..0.6).contains(&lb),
        "blocking LB fraction {lb} outside plausible band"
    );
}

#[test]
fn deeper_models_amortize_exposed_edges() {
    // Only block 0's Trans/Agg are exposed; with more blocks their share
    // of total time must shrink.
    let c12 = real_costs(12);
    let c24: Vec<BlockCosts> = real_costs(24);
    let f12 = build_blockwise(&c12).lb_fraction();
    let f24 = build_blockwise(&c24).lb_fraction();
    assert!(
        f24 <= f12 + 0.02,
        "deeper model should not increase exposed LB fraction: {f24} vs {f12}"
    );
}

#[test]
fn schedules_are_deterministic() {
    let costs = real_costs(6);
    let a = build_blockwise(&costs).total_time();
    let b = build_blockwise(&costs).total_time();
    assert_eq!(a, b);
}
