//! Integration: end-to-end trainer through the AOT artifacts (tiny preset)
//! and the trainer -> planner/simulator hand-off.

use pro_prophet::balancer::{registry, ProphetOptions};
use pro_prophet::config::TrainingConfig;
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::runtime;
use pro_prophet::sim::simulate_policy;
use pro_prophet::trainer::Trainer;

fn available() -> bool {
    if runtime::artifacts_available("tiny") {
        true
    } else {
        eprintln!("SKIP: tiny artifacts not built");
        false
    }
}

#[test]
fn trainer_runs_and_loss_is_finite() {
    if !available() {
        return;
    }
    let mut t = Trainer::new(TrainingConfig {
        preset: "tiny".into(),
        steps: 12,
        seed: 1,
        ..Default::default()
    })
    .unwrap();
    let report = t.run(12, |_| {}).unwrap();
    assert_eq!(report.losses.len(), 12);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    // Early loss is near log(V=64) ~ 4.16 for an untrained model.
    assert!((3.0..6.0).contains(&report.initial_loss()));
}

#[test]
fn trainer_learns_on_structured_corpus() {
    if !available() {
        return;
    }
    let mut t = Trainer::new(TrainingConfig {
        preset: "tiny".into(),
        steps: 120,
        seed: 2,
        ..Default::default()
    })
    .unwrap();
    let report = t.run(120, |_| {}).unwrap();
    let head = report.losses[..10].iter().sum::<f32>() / 10.0;
    let tail = report.mean_loss_tail(10);
    assert!(
        tail < head - 0.1,
        "no learning signal: {head:.3} -> {tail:.3}"
    );
}

#[test]
fn trainer_is_deterministic_per_seed() {
    if !available() {
        return;
    }
    let run = |seed: u64| {
        let mut t = Trainer::new(TrainingConfig {
            preset: "tiny".into(),
            seed,
            ..Default::default()
        })
        .unwrap();
        t.run(5, |_| {}).unwrap().losses
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

#[test]
fn loads_are_conserved_and_feed_the_simulator() {
    if !available() {
        return;
    }
    let mut t = Trainer::new(TrainingConfig {
        preset: "tiny".into(),
        seed: 5,
        ..Default::default()
    })
    .unwrap();
    let man = t.manifest.clone();
    let report = t.run(8, |_| {}).unwrap();
    // Histogram totals = tokens * k for every step and layer.
    for step_loads in &report.loads {
        assert_eq!(step_loads.len(), man.n_layers);
        for hist in step_loads {
            let total: u64 = hist.iter().sum();
            assert_eq!(total as usize, man.tokens_per_step * man.k);
        }
    }
    // Real loads drive the simulator end to end.  The tiny preset's 64
    // tokens/step make one simulated iteration a few microseconds — far
    // below the Plan primitive's fixed cost — so the histograms are
    // scaled to a production-sized iteration (the RELATIVE routing skew,
    // which is what the planner consumes, is preserved exactly).
    const SCALE: u64 = 512;
    let mut scaled = report.clone();
    for step in &mut scaled.loads {
        for hist in step {
            for c in hist.iter_mut() {
                *c *= SCALE;
            }
        }
    }
    let trace = scaled.to_trace(man.n_experts);
    let model = ModelSpec::new(
        "tiny-real",
        man.n_layers,
        man.d_model,
        man.d_ff,
        man.n_experts,
        man.k,
        (man.tokens_per_step * man.k) as u64 * SCALE,
    );
    let cluster = ClusterSpec::hpwnv(1);
    let opts = ProphetOptions::full();
    let ds = simulate_policy(
        &model,
        &cluster,
        &trace,
        registry::build("deepspeed", &opts).unwrap(),
    );
    let pp = simulate_policy(
        &model,
        &cluster,
        &trace,
        registry::build("pro-prophet", &opts).unwrap(),
    );
    assert!(ds.avg_iter_time() > 0.0);
    // The tiny preset's real routing is nearly balanced (64 tokens over 4
    // experts), so the planner mostly returns identity placements and the
    // two policies tie; Pro-Prophet may carry a sliver of exposed Plan
    // cost that the tiny A2A cannot hide.  It must never be meaningfully
    // slower, and on skewed workloads it must win (integration_sim).
    assert!(
        pp.avg_iter_time() <= ds.avg_iter_time() * 1.05 + 1e-9,
        "prophet {} vs deepspeed {}",
        pp.avg_iter_time(),
        ds.avg_iter_time()
    );
}

#[test]
fn eval_step_runs() {
    if !available() {
        return;
    }
    let mut t = Trainer::new(TrainingConfig {
        preset: "tiny".into(),
        seed: 6,
        ..Default::default()
    })
    .unwrap();
    let _ = t.run(2, |_| {}).unwrap();
    let loss = t.eval().unwrap();
    assert!(loss.is_finite());
}
