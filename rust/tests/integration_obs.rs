//! Integration: the telemetry layer end to end — no-op equivalence
//! (telemetry off changes nothing, bit for bit), hub-instrumented runs
//! (telemetry on STILL changes nothing, and records real data), the
//! schema-versioned JSONL sink with bounded retention, the
//! `PRO_PROPHET_RESULT_DIR` override, and the `report`/`--metrics` CLI
//! surface over a shipped example config.

use pro_prophet::balancer::{registry, ProphetOptions};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::obs::{self, report, Labels, Recorder, TelemetryHub};
use pro_prophet::sim::{simulate_policy, simulate_policy_with, SimReport};
use pro_prophet::util::json;
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};
use std::path::PathBuf;
use std::process::{Command, Output};
use std::sync::Arc;

fn run(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_pro-prophet"))
        .args(args)
        .output()
        .expect("failed to spawn pro-prophet binary")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pro_prophet_obs_{}_{name}", std::process::id()))
}

fn scenario(iters: usize) -> (ModelSpec, ClusterSpec, Trace) {
    let cluster = ClusterSpec::hpwnv(2); // 8 devices
    let d = cluster.n_devices();
    let model = ModelSpec::moe_gpt_s(d, 1, 4096);
    let mut wcfg = WorkloadConfig::paper_default(model.n_layers, d, d, 4096);
    wcfg.seed = 7;
    let trace = Trace::capture(&mut WorkloadGen::new(wcfg), iters);
    (model, cluster, trace)
}

fn prophet_report(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    rec: Option<Arc<dyn Recorder>>,
) -> SimReport {
    let policy = registry::build("pro-prophet", &ProphetOptions::default()).unwrap();
    match rec {
        Some(r) => simulate_policy_with(model, cluster, trace, policy, r),
        None => simulate_policy(model, cluster, trace, policy),
    }
}

fn assert_reports_bitwise(a: &SimReport, b: &SimReport) {
    assert_eq!(a.policy, b.policy);
    assert_eq!(a.plans_run, b.plans_run);
    assert_eq!(a.plans_reused, b.plans_reused);
    assert_eq!(a.drift_replans, b.drift_replans);
    assert_eq!(a.iters.len(), b.iters.len());
    for (i, (x, y)) in a.iters.iter().zip(&b.iters).enumerate() {
        assert_eq!(x.time.to_bits(), y.time.to_bits(), "iter {i}: time");
        assert_eq!(
            x.barrier_time.to_bits(),
            y.barrier_time.to_bits(),
            "iter {i}: barrier_time"
        );
        assert_eq!(x.des_time.to_bits(), y.des_time.to_bits(), "iter {i}: des_time");
        assert_eq!(
            x.balance_after.to_bits(),
            y.balance_after.to_bits(),
            "iter {i}: balance_after"
        );
        assert_eq!(x.trans_copies, y.trans_copies, "iter {i}: trans_copies");
        assert_eq!(x.straggler, y.straggler, "iter {i}: straggler");
    }
}

#[test]
fn schema_version_is_pinned() {
    // The schema string IS the compatibility contract between producers
    // (TelemetryHub::to_jsonl) and consumers (report::parse_jsonl, any
    // external tooling).  Changing it is a breaking change: bump the
    // version suffix AND teach parse_jsonl the old one if needed.
    assert_eq!(obs::SCHEMA, "pro-prophet-metrics/v1");
}

#[test]
fn telemetry_off_is_bit_identical() {
    // simulate_policy is simulate_policy_with(noop): same object graph,
    // same result bits — the golden-equivalence suite rides on this.
    let (model, cluster, trace) = scenario(4);
    let plain = prophet_report(&model, &cluster, &trace, None);
    let noop = prophet_report(&model, &cluster, &trace, Some(obs::noop_arc()));
    assert_reports_bitwise(&plain, &noop);
}

#[test]
fn telemetry_on_records_without_perturbing() {
    let (model, cluster, trace) = scenario(4);
    let plain = prophet_report(&model, &cluster, &trace, None);
    let hub = Arc::new(TelemetryHub::new());
    let live = prophet_report(&model, &cluster, &trace, Some(hub.clone()));
    // Recording must not move a single bit of the simulation.
    assert_reports_bitwise(&plain, &live);
    // ...and must actually have recorded the run.
    assert_eq!(hub.iterations_seen(), 4);
    assert!(hub.counter_total("des.events", Labels::None) > 0);
    assert!(hub.counter_total("plan.searches", Labels::None) > 0);
    for span in ["sim.iteration", "balancer.decide", "des.execute", "prophet.forecast"] {
        let agg = hub.span_agg(span, Labels::None);
        assert!(agg.is_some(), "span {span} missing");
        assert!(agg.unwrap().count > 0, "span {span} empty");
    }
    let straggler = hub.gauge_agg("des.straggler_device", Labels::None).unwrap();
    assert!(straggler.last >= 0.0);
    // Per-device gauges carry one labeled series per device.
    for dev in 0..cluster.n_devices() {
        assert!(
            hub.gauge_agg("des.device_idle_s", Labels::one("dev", dev as i64)).is_some(),
            "no idle gauge for dev {dev}"
        );
    }
}

#[test]
fn jsonl_file_round_trip() {
    let (model, cluster, trace) = scenario(3);
    let hub = Arc::new(TelemetryHub::new());
    hub.set_meta("tool", json::s("test"));
    prophet_report(&model, &cluster, &trace, Some(hub.clone()));
    let path = tmp("round_trip.jsonl");
    let stats = hub.write_jsonl(&path).unwrap();
    assert_eq!(stats.iterations, 3);
    assert_eq!(stats.recorded, 3);
    assert_eq!(stats.dropped, 0);
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // Every line is standalone JSON carrying the schema tag.
    for (i, line) in text.lines().enumerate() {
        let v = json::parse(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
        assert_eq!(v.get("schema").and_then(json::Json::as_str), Some(obs::SCHEMA));
    }
    let doc = report::parse_jsonl(&text).unwrap();
    assert_eq!(doc.iterations, 3);
    assert_eq!(doc.recorded, 3);
    assert_eq!(
        doc.counters.get("des.events").copied(),
        Some(hub.counter_total("des.events", Labels::None) as f64)
    );
    assert!(doc.spans.contains_key("des.execute"));
    assert!(doc.meta.contains_key("tool"));
}

#[test]
fn bounded_sink_reports_exact_drops() {
    let (model, cluster, trace) = scenario(5);
    let hub = Arc::new(TelemetryHub::with_max_events(2));
    prophet_report(&model, &cluster, &trace, Some(hub.clone()));
    let stats = hub.stats();
    assert_eq!(stats.iterations, 5);
    assert_eq!(stats.recorded, 2);
    assert_eq!(stats.dropped, 3);
    let msg = stats.drop_message().expect("drops must be reported");
    assert!(msg.contains("dropped 3 of 5"), "{msg}");
    // Whole-run aggregates still saw every iteration.
    let agg = hub.span_agg("sim.iteration", Labels::None).unwrap();
    assert_eq!(agg.count, 5);
    // The parsed doc reflects the cap too.
    let doc = report::parse_jsonl(&hub.to_jsonl()).unwrap();
    assert_eq!(doc.recorded, 2);
    assert_eq!(doc.dropped, 3);
}

#[test]
fn result_dir_env_override_is_honored() {
    // metrics::write_result normally writes under bench_results/; the
    // PRO_PROPHET_RESULT_DIR override redirects it (used by CI to stage
    // artifacts without cd'ing around).
    let dir = tmp("result_dir");
    std::fs::create_dir_all(&dir).unwrap();
    std::env::set_var("PRO_PROPHET_RESULT_DIR", &dir);
    let path = pro_prophet::metrics::write_result(
        "obs_env_override",
        &json::obj(vec![("ok", json::num(1.0))]),
    )
    .unwrap();
    std::env::remove_var("PRO_PROPHET_RESULT_DIR");
    assert_eq!(path.parent(), Some(dir.as_path()), "wrote to {}", path.display());
    assert!(path.exists());
    std::fs::remove_dir_all(&dir).ok();
}

// --- CLI surface -------------------------------------------------------------

#[test]
fn cli_simulate_metrics_then_report_and_diff() {
    let metrics = tmp("cli_run.jsonl");
    let metrics_s = metrics.to_str().unwrap();
    let out = run(&[
        "simulate", "--model", "s", "--nodes", "1", "--tokens", "2048", "--iters", "3",
        "--policy", "pro-prophet", "--metrics", metrics_s,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("metrics:"), "{stdout}");
    let doc = report::parse_jsonl(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert_eq!(doc.recorded, 3);
    assert!(doc.spans.contains_key("plan.greedy_search"), "{:?}", doc.metric_names());
    assert_eq!(doc.meta.get("tool").and_then(json::Json::as_str), Some("simulate"));
    assert_eq!(doc.meta.get("policy").and_then(json::Json::as_str), Some("pro-prophet"));

    // Render it.
    let rep = run(&["report", "--metrics", metrics_s]);
    assert!(rep.status.success(), "{}", String::from_utf8_lossy(&rep.stderr));
    let rendered = String::from_utf8_lossy(&rep.stdout);
    assert!(rendered.contains("span timings"), "{rendered}");
    assert!(rendered.contains("des.execute"), "{rendered}");
    assert!(rendered.contains("counters"), "{rendered}");

    // Substring filter narrows the tables; unknown metrics error.
    let filt = run(&["report", "--metrics", metrics_s, "--metric", "des."]);
    assert!(filt.status.success());
    let filtered = String::from_utf8_lossy(&filt.stdout);
    assert!(filtered.contains("des.execute") && !filtered.contains("plan.greedy_search"));
    let unknown = run(&["report", "--metrics", metrics_s, "--metric", "warpdrive"]);
    assert!(!unknown.status.success());
    assert!(
        String::from_utf8_lossy(&unknown.stderr).contains("unknown metric"),
        "{}",
        String::from_utf8_lossy(&unknown.stderr)
    );

    // A/B diff against a second (straggler) run.
    let base = tmp("cli_base.jsonl");
    let base_s = base.to_str().unwrap();
    let out2 = run(&[
        "simulate", "--model", "s", "--nodes", "1", "--tokens", "2048", "--iters", "3",
        "--policy", "pro-prophet", "--straggler", "1", "--metrics", base_s,
    ]);
    assert!(out2.status.success(), "{}", String::from_utf8_lossy(&out2.stderr));
    let diff = run(&["report", "--metrics", metrics_s, "--baseline", base_s]);
    assert!(diff.status.success(), "{}", String::from_utf8_lossy(&diff.stderr));
    let diffed = String::from_utf8_lossy(&diff.stdout);
    assert!(diffed.contains("A/B metric deltas"), "{diffed}");
    assert!(diffed.contains("des.makespan_s.mean"), "{diffed}");
    std::fs::remove_file(&metrics).ok();
    std::fs::remove_file(&base).ok();
}

#[test]
fn cli_report_rejects_malformed_files() {
    let bad = tmp("malformed.jsonl");
    std::fs::write(&bad, "this is not json\n").unwrap();
    let out = run(&["report", "--metrics", bad.to_str().unwrap()]);
    assert!(!out.status.success(), "malformed file must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("line 1"), "{stderr}");
    std::fs::remove_file(&bad).ok();

    // Missing --metrics is a usage error, not a panic.
    let none = run(&["report"]);
    assert!(!none.status.success());
    assert!(String::from_utf8_lossy(&none.stderr).contains("--metrics"));
}

#[test]
fn cli_metrics_max_events_caps_and_reports() {
    let metrics = tmp("cli_capped.jsonl");
    let metrics_s = metrics.to_str().unwrap();
    let out = run(&[
        "simulate", "--model", "s", "--nodes", "1", "--tokens", "2048", "--iters", "5",
        "--policy", "deepspeed", "--metrics", metrics_s, "--max-events", "2",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("dropped 3 of 5"), "{stdout}");
    let doc = report::parse_jsonl(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert_eq!(doc.recorded, 2);
    assert_eq!(doc.dropped, 3);
    assert_eq!(doc.iterations, 5);
    std::fs::remove_file(&metrics).ok();

    // --max-events 0 is rejected up front.
    let zero = run(&["simulate", "--nodes", "1", "--iters", "1", "--max-events", "0"]);
    assert!(!zero.status.success());
    assert!(String::from_utf8_lossy(&zero.stderr).contains("max-events"));
}

#[test]
fn cli_chrome_trace_carries_counter_tracks() {
    let trace_path = tmp("chrome.json");
    let out = run(&[
        "simulate", "--model", "s", "--nodes", "1", "--tokens", "2048", "--iters", "2",
        "--policy", "pro-prophet", "--chrome-trace", trace_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let parsed = json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    std::fs::remove_file(&trace_path).ok();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let counter_names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(json::Json::as_str) == Some("C"))
        .filter_map(|e| e.get("name").and_then(json::Json::as_str))
        .collect();
    assert!(counter_names.contains(&"balance_degree"), "{counter_names:?}");
    assert!(counter_names.contains(&"straggler"), "{counter_names:?}");
    assert!(counter_names.contains(&"exposed_comm_s"), "{counter_names:?}");
}

#[test]
fn cli_config_straggler_run_records_per_device_story() {
    // The acceptance scenario: the shipped straggler config through
    // `simulate --config ... --metrics`, rendered by `report`.  Device 5
    // runs 2.5x slow; the metrics must carry the span-timed hot paths
    // AND the per-device straggler stats.
    let metrics = tmp("straggler.jsonl");
    let metrics_s = metrics.to_str().unwrap();
    let out = run(&[
        "simulate", "--config", "examples/configs/hpwnv16_straggler.toml",
        "--iters", "3", "--metrics", metrics_s,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = report::parse_jsonl(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert_eq!(doc.recorded, 3);
    // Span-timed phases: forecast, search, DES.
    for span in ["prophet.forecast", "plan.greedy_search", "des.lower", "des.execute"] {
        assert!(doc.spans.contains_key(span), "span {span} missing: {:?}", doc.metric_names());
    }
    // The DES pinpoints the configured straggler...
    let straggler = doc.gauges.get("des.straggler_device").unwrap();
    assert_eq!(straggler.last, 5.0, "{straggler:?}");
    // ...and carries per-device busy/idle series for all 16 devices.
    for dev in 0..16 {
        assert!(
            doc.gauges.contains_key(&format!("des.device_idle_s{{dev={dev}}}")),
            "idle gauge for dev {dev} missing"
        );
    }
    assert!(doc.gauges.contains_key("des.device_busy_comp_s{dev=5}"));
    // report renders it without complaint.
    let rep = run(&["report", "--metrics", metrics_s, "--metric", "des.device_idle_s"]);
    assert!(rep.status.success(), "{}", String::from_utf8_lossy(&rep.stderr));
    let rendered = String::from_utf8_lossy(&rep.stdout);
    assert!(rendered.contains("des.device_idle_s{dev=5}"), "{rendered}");
    std::fs::remove_file(&metrics).ok();
}
