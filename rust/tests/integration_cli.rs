//! Integration: the `pro-prophet` binary — policy-registry listings,
//! unknown-name error paths, and the `trace --from-store` round trip
//! (recorded prophet history → workload trace).

use pro_prophet::balancer::registry;
use pro_prophet::prophet::TraceStore;
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};
use std::path::PathBuf;
use std::process::{Command, Output};

fn run(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_pro-prophet"))
        .args(args)
        .output()
        .expect("failed to spawn pro-prophet binary")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pro_prophet_cli_{}_{name}", std::process::id()))
}

fn small_trace(iters: usize) -> Trace {
    let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(2, 4, 4, 1024));
    Trace::capture(&mut gen, iters)
}

#[test]
fn help_lists_the_policy_registry() {
    let out = run(&["simulate", "--help"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in registry::names() {
        assert!(stdout.contains(name), "--help output misses policy {name:?}");
    }
}

#[test]
fn info_lists_the_policy_registry() {
    let out = run(&["info"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("registered balancing policies"), "{stdout}");
    for name in ["deepspeed", "fastermoe", "flexmoe", "pro-prophet"] {
        assert!(stdout.contains(name), "info output misses policy {name:?}");
    }
}

#[test]
fn simulate_schedule_flag_selects_the_relaxed_mode() {
    // `--schedule dag_relaxed` flips the Pro-Prophet row into the relaxed
    // execution mode; the table shows the new relaxed-vs-barrier column.
    let out = run(&[
        "simulate",
        "--model",
        "s",
        "--cluster",
        "hpwnv",
        "--nodes",
        "1",
        "--tokens",
        "2048",
        "--iters",
        "2",
        "--policy",
        "pro-prophet",
        "--schedule",
        "dag_relaxed",
    ]);
    assert!(
        out.status.success(),
        "simulate --schedule failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Pro-Prophet(dag)"), "{stdout}");
    assert!(stdout.contains("barrier_s"), "relaxed-vs-barrier column missing: {stdout}");

    // Unknown kinds fail fast and list the known spellings.
    let bad = run(&["simulate", "--nodes", "1", "--iters", "1", "--schedule", "warp"]);
    assert!(!bad.status.success(), "unknown --schedule must be an error");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("unknown --schedule"), "{stderr}");
    assert!(stderr.contains("dag_relaxed") && stderr.contains("blockwise"), "{stderr}");

    // no_load_balance is a policy choice, not a scheduling mode: it is
    // rejected with a pointer instead of silently pricing Blocking.
    let bad = run(&["simulate", "--nodes", "1", "--iters", "1", "--schedule", "no_load_balance"]);
    assert!(!bad.status.success());
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("deepspeed"),
        "rejection should point at --policy deepspeed"
    );
}

#[test]
fn default_simulate_table_has_the_dag_row() {
    let out = run(&[
        "simulate",
        "--model",
        "s",
        "--cluster",
        "hpwnv",
        "--nodes",
        "1",
        "--tokens",
        "2048",
        "--iters",
        "2",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for row in ["Deepspeed-MoE", "FasterMoE", "FlexMoE", "Pro-Prophet", "Pro-Prophet(dag)"] {
        assert!(stdout.contains(row), "default table misses {row:?}: {stdout}");
    }
}

#[test]
fn unknown_policy_fails_fast_with_known_list() {
    let out = run(&["simulate", "--policy", "warlock", "--iters", "1"]);
    assert!(!out.status.success(), "unknown policy must be an error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown policy"), "{stderr}");
    assert!(stderr.contains("pro-prophet"), "error should list known names: {stderr}");
}

#[test]
fn simulate_straggler_flags_and_per_device_trace() {
    let trace_path = tmp("lanes.json");
    let out = run(&[
        "simulate",
        "--model",
        "s",
        "--cluster",
        "hpwnv",
        "--nodes",
        "1",
        "--tokens",
        "2048",
        "--iters",
        "2",
        "--policy",
        "deepspeed",
        "--straggler",
        "1",
        "--straggler-slowdown",
        "2.5",
        "--chrome-trace",
        trace_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "simulate --straggler failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    // The per-device section appears (which device wins depends on how
    // the slowdown interacts with the workload skew).
    assert!(stdout.contains("straggler dev"), "{stdout}");
    assert!(stdout.contains("per-device slowdowns"), "{stdout}");
    assert!(stdout.contains("des_s"), "per-device DES column missing: {stdout}");
    // The exported Chrome trace has per-device lanes.
    let json = std::fs::read_to_string(&trace_path).unwrap();
    assert!(json.contains("dev1 comp") && json.contains("dev1 comm"), "no device lanes");
    let _ = std::fs::remove_file(&trace_path);

    // Out-of-range straggler fails fast.
    let bad = run(&["simulate", "--nodes", "1", "--iters", "1", "--straggler", "99"]);
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("out of range"));
}

#[test]
fn trace_from_store_round_trips() {
    // A "recorded run": the prophet's history ring buffer persisted via
    // TraceStore (what `train --save-store` writes).
    let recorded = small_trace(4);
    let mut store = TraceStore::new(8);
    for layers in &recorded.iterations {
        store.push(layers.clone());
    }
    let store_path = tmp("store.txt");
    let out_path = tmp("reexport.txt");
    store.save(&store_path).unwrap();

    let out = run(&[
        "trace",
        "--from-store",
        store_path.to_str().unwrap(),
        "--out",
        out_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "trace --from-store failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let exported = Trace::load(&out_path).unwrap();
    assert_eq!(exported, recorded, "round trip must be lossless");

    // --iters keeps only the NEWEST n iterations (ring-buffer semantics).
    let out2 = run(&[
        "trace",
        "--from-store",
        store_path.to_str().unwrap(),
        "--out",
        out_path.to_str().unwrap(),
        "--iters",
        "2",
    ]);
    assert!(out2.status.success());
    let tail = Trace::load(&out_path).unwrap();
    assert_eq!(tail.len(), 2);
    assert_eq!(tail.iterations[..], recorded.iterations[2..]);

    let _ = std::fs::remove_file(&store_path);
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn simulate_fault_flags_happy_and_error_paths() {
    // --faults FILE: one event spec per line, comments allowed.
    let faults_path = tmp("faults.txt");
    std::fs::write(
        &faults_path,
        "# device 2 thermally throttles for two iterations\n\
         transient dev=2 factor=2.5 start=1 dur=2\n",
    )
    .unwrap();
    let base = [
        "simulate", "--model", "s", "--cluster", "hpwnv", "--nodes", "1", "--tokens",
        "2048", "--iters", "4", "--policy", "deepspeed",
    ];
    let mut with_file = base.to_vec();
    with_file.extend(["--faults", faults_path.to_str().unwrap()]);
    let out = run(&with_file);
    assert!(
        out.status.success(),
        "simulate --faults failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("[simulate] faults:"), "{stdout}");
    assert!(stdout.contains("transient dev=2"), "{stdout}");

    // --fault-seed S: a synthetic timeline sized to the run.
    let mut with_seed = base.to_vec();
    with_seed.extend(["--fault-seed", "7"]);
    let out = run(&with_seed);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("[simulate] faults:"),
        "seeded timeline must be announced"
    );

    // The two sources are mutually exclusive.
    let mut both = with_file.clone();
    both.extend(["--fault-seed", "7"]);
    let out = run(&both);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // A malformed spec names the file and the offending event.
    let bad_path = tmp("faults_bad.txt");
    std::fs::write(&bad_path, "explode dev=1 start=0\n").unwrap();
    let mut bad = base.to_vec();
    bad.extend(["--faults", bad_path.to_str().unwrap()]);
    let out = run(&bad);
    assert!(!out.status.success(), "malformed fault spec must be an error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--faults"), "{stderr}");

    // Non-integer seeds fail fast.
    let mut lucky = base.to_vec();
    lucky.extend(["--fault-seed", "lucky"]);
    let out = run(&lucky);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--fault-seed"));

    let _ = std::fs::remove_file(&faults_path);
    let _ = std::fs::remove_file(&bad_path);
}

#[test]
fn simulate_checkpoint_kill_and_resume_reproduces_the_report() {
    let dir = tmp("ckpt_dir");
    let _ = std::fs::remove_dir_all(&dir);
    let a_json = tmp("ckpt_a.json");
    let b_json = tmp("ckpt_b.json");
    let c_json = tmp("ckpt_c.json");
    let base = [
        "simulate", "--model", "s", "--cluster", "hpwnv", "--nodes", "1", "--tokens",
        "2048", "--iters", "4", "--policy", "pro-prophet", "--fault-seed", "3",
    ];

    // The "killed" run: stop after 2 of 4 iterations, checkpointing.
    let mut killed = base.to_vec();
    killed.extend([
        "--stop-after", "2",
        "--checkpoint", dir.to_str().unwrap(),
        "--checkpoint-every", "1",
        "--report-json", a_json.to_str().unwrap(),
    ]);
    let out = run(&killed);
    assert!(
        out.status.success(),
        "checkpointed run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("[simulate] report"),
        "--report-json must be announced"
    );
    assert!(dir.join("checkpoint.json").exists(), "checkpoint file missing");

    // Resume to completion, and run straight through for comparison.
    let mut resumed = base.to_vec();
    resumed.extend([
        "--checkpoint", dir.to_str().unwrap(),
        "--resume",
        "--report-json", b_json.to_str().unwrap(),
    ]);
    let out = run(&resumed);
    assert!(
        out.status.success(),
        "resumed run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let mut straight = base.to_vec();
    straight.extend(["--report-json", c_json.to_str().unwrap()]);
    let out = run(&straight);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let b = std::fs::read_to_string(&b_json).unwrap();
    let c = std::fs::read_to_string(&c_json).unwrap();
    assert_eq!(b, c, "resumed SimReport must be byte-identical to the straight run");
    assert_ne!(
        std::fs::read_to_string(&a_json).unwrap(),
        c,
        "the truncated run must differ from the full one"
    );

    let _ = std::fs::remove_dir_all(&dir);
    for p in [&a_json, &b_json, &c_json] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn simulate_checkpoint_flag_validation() {
    // --resume without --checkpoint is meaningless.
    let out = run(&["simulate", "--nodes", "1", "--iters", "2", "--resume"]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("requires --checkpoint"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // --checkpoint-every 0 would never write anything.
    let out = run(&[
        "simulate", "--nodes", "1", "--iters", "2", "--policy", "deepspeed",
        "--checkpoint", "/tmp/never", "--checkpoint-every", "0",
    ]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains(">= 1"));

    // Single-run flags demand a single --policy (the default table runs
    // five).
    let out = run(&[
        "simulate", "--nodes", "1", "--iters", "2", "--report-json", "/tmp/never.json",
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("single run"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn trace_from_store_rejects_missing_or_empty() {
    let out = run(&[
        "trace",
        "--from-store",
        "/nonexistent/prophet_store.txt",
        "--out",
        tmp("never.txt").to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("load store"), "{stderr}");
}
