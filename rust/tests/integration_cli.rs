//! Integration: the `pro-prophet` binary — policy-registry listings,
//! unknown-name error paths, and the `trace --from-store` round trip
//! (recorded prophet history → workload trace).

use pro_prophet::balancer::registry;
use pro_prophet::prophet::TraceStore;
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};
use std::path::PathBuf;
use std::process::{Command, Output};

fn run(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_pro-prophet"))
        .args(args)
        .output()
        .expect("failed to spawn pro-prophet binary")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pro_prophet_cli_{}_{name}", std::process::id()))
}

fn small_trace(iters: usize) -> Trace {
    let mut gen = WorkloadGen::new(WorkloadConfig::paper_default(2, 4, 4, 1024));
    Trace::capture(&mut gen, iters)
}

#[test]
fn help_lists_the_policy_registry() {
    let out = run(&["simulate", "--help"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in registry::names() {
        assert!(stdout.contains(name), "--help output misses policy {name:?}");
    }
}

#[test]
fn info_lists_the_policy_registry() {
    let out = run(&["info"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("registered balancing policies"), "{stdout}");
    for name in ["deepspeed", "fastermoe", "flexmoe", "pro-prophet"] {
        assert!(stdout.contains(name), "info output misses policy {name:?}");
    }
}

#[test]
fn simulate_schedule_flag_selects_the_relaxed_mode() {
    // `--schedule dag_relaxed` flips the Pro-Prophet row into the relaxed
    // execution mode; the table shows the new relaxed-vs-barrier column.
    let out = run(&[
        "simulate",
        "--model",
        "s",
        "--cluster",
        "hpwnv",
        "--nodes",
        "1",
        "--tokens",
        "2048",
        "--iters",
        "2",
        "--policy",
        "pro-prophet",
        "--schedule",
        "dag_relaxed",
    ]);
    assert!(
        out.status.success(),
        "simulate --schedule failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Pro-Prophet(dag)"), "{stdout}");
    assert!(stdout.contains("barrier_s"), "relaxed-vs-barrier column missing: {stdout}");

    // Unknown kinds fail fast and list the known spellings.
    let bad = run(&["simulate", "--nodes", "1", "--iters", "1", "--schedule", "warp"]);
    assert!(!bad.status.success(), "unknown --schedule must be an error");
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("unknown --schedule"), "{stderr}");
    assert!(stderr.contains("dag_relaxed") && stderr.contains("blockwise"), "{stderr}");

    // no_load_balance is a policy choice, not a scheduling mode: it is
    // rejected with a pointer instead of silently pricing Blocking.
    let bad = run(&["simulate", "--nodes", "1", "--iters", "1", "--schedule", "no_load_balance"]);
    assert!(!bad.status.success());
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("deepspeed"),
        "rejection should point at --policy deepspeed"
    );
}

#[test]
fn default_simulate_table_has_the_dag_row() {
    let out = run(&[
        "simulate",
        "--model",
        "s",
        "--cluster",
        "hpwnv",
        "--nodes",
        "1",
        "--tokens",
        "2048",
        "--iters",
        "2",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for row in ["Deepspeed-MoE", "FasterMoE", "FlexMoE", "Pro-Prophet", "Pro-Prophet(dag)"] {
        assert!(stdout.contains(row), "default table misses {row:?}: {stdout}");
    }
}

#[test]
fn unknown_policy_fails_fast_with_known_list() {
    let out = run(&["simulate", "--policy", "warlock", "--iters", "1"]);
    assert!(!out.status.success(), "unknown policy must be an error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown policy"), "{stderr}");
    assert!(stderr.contains("pro-prophet"), "error should list known names: {stderr}");
}

#[test]
fn simulate_straggler_flags_and_per_device_trace() {
    let trace_path = tmp("lanes.json");
    let out = run(&[
        "simulate",
        "--model",
        "s",
        "--cluster",
        "hpwnv",
        "--nodes",
        "1",
        "--tokens",
        "2048",
        "--iters",
        "2",
        "--policy",
        "deepspeed",
        "--straggler",
        "1",
        "--straggler-slowdown",
        "2.5",
        "--chrome-trace",
        trace_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "simulate --straggler failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    // The per-device section appears (which device wins depends on how
    // the slowdown interacts with the workload skew).
    assert!(stdout.contains("straggler dev"), "{stdout}");
    assert!(stdout.contains("per-device slowdowns"), "{stdout}");
    assert!(stdout.contains("des_s"), "per-device DES column missing: {stdout}");
    // The exported Chrome trace has per-device lanes.
    let json = std::fs::read_to_string(&trace_path).unwrap();
    assert!(json.contains("dev1 comp") && json.contains("dev1 comm"), "no device lanes");
    let _ = std::fs::remove_file(&trace_path);

    // Out-of-range straggler fails fast.
    let bad = run(&["simulate", "--nodes", "1", "--iters", "1", "--straggler", "99"]);
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("out of range"));
}

#[test]
fn trace_from_store_round_trips() {
    // A "recorded run": the prophet's history ring buffer persisted via
    // TraceStore (what `train --save-store` writes).
    let recorded = small_trace(4);
    let mut store = TraceStore::new(8);
    for layers in &recorded.iterations {
        store.push(layers.clone());
    }
    let store_path = tmp("store.txt");
    let out_path = tmp("reexport.txt");
    store.save(&store_path).unwrap();

    let out = run(&[
        "trace",
        "--from-store",
        store_path.to_str().unwrap(),
        "--out",
        out_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "trace --from-store failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let exported = Trace::load(&out_path).unwrap();
    assert_eq!(exported, recorded, "round trip must be lossless");

    // --iters keeps only the NEWEST n iterations (ring-buffer semantics).
    let out2 = run(&[
        "trace",
        "--from-store",
        store_path.to_str().unwrap(),
        "--out",
        out_path.to_str().unwrap(),
        "--iters",
        "2",
    ]);
    assert!(out2.status.success());
    let tail = Trace::load(&out_path).unwrap();
    assert_eq!(tail.len(), 2);
    assert_eq!(tail.iterations[..], recorded.iterations[2..]);

    let _ = std::fs::remove_file(&store_path);
    let _ = std::fs::remove_file(&out_path);
}

#[test]
fn trace_from_store_rejects_missing_or_empty() {
    let out = run(&[
        "trace",
        "--from-store",
        "/nonexistent/prophet_store.txt",
        "--out",
        tmp("never.txt").to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("load store"), "{stderr}");
}
