//! Integration: planner over realistic workloads and cluster presets —
//! the paper's §IV claims at module-composition level.

use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::balance_degree;
use pro_prophet::moe::Placement;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{greedy_search, policies, Planner, PlannerConfig};
use pro_prophet::workload::{WorkloadConfig, WorkloadGen};

fn setup(e: usize, nodes: usize) -> (ModelSpec, ClusterSpec, PerfModel, WorkloadGen) {
    let model = ModelSpec::moe_gpt_m(e, 1, 16384);
    let cluster = ClusterSpec::hpwnv(nodes);
    let pm = PerfModel::new(&model, &cluster);
    let gen = WorkloadGen::new(WorkloadConfig::paper_default(4, e, cluster.n_devices(), 16384));
    (model, cluster, pm, gen)
}

#[test]
fn planner_improves_every_layer_of_a_real_trace() {
    let (_, _, pm, mut gen) = setup(16, 4);
    let layers = gen.next_iteration();
    for (l, w) in layers.iter().enumerate() {
        let r = greedy_search(w, &pm, &PlannerConfig::default());
        assert!(
            r.t_est <= r.t_identity + 1e-12,
            "layer {l}: {} > {}",
            r.t_est,
            r.t_identity
        );
        // On these skewed workloads the planner should find real wins.
        assert!(
            r.t_est < 0.95 * r.t_identity,
            "layer {l}: no meaningful improvement ({} vs {})",
            r.t_est,
            r.t_identity
        );
        r.placement.validate().unwrap();
    }
}

#[test]
fn planner_beats_fastermoe_balance_on_average() {
    // Fig 16: the planner achieves higher RB than FasterMoE in most layers.
    let (_, _, pm, mut gen) = setup(16, 4);
    let mut wins = 0;
    let mut total = 0;
    for _ in 0..3 {
        for w in gen.next_iteration() {
            let prophet = greedy_search(&w, &pm, &PlannerConfig::default()).placement;
            let faster = policies::fastermoe_shadowing(&w, &pm);
            let b_ident = balance_degree(&w.route_identity().h);
            let b_prophet = balance_degree(&w.route(&prophet).h);
            let b_faster = balance_degree(&w.route(&faster).h);
            let rb_prophet = b_ident / b_prophet.max(1e-9);
            let rb_faster = b_ident / b_faster.max(1e-9);
            if rb_prophet >= rb_faster {
                wins += 1;
            }
            total += 1;
        }
    }
    assert!(
        wins * 2 > total,
        "planner RB should beat FasterMoE in most layers: {wins}/{total}"
    );
}

#[test]
fn locality_reduces_search_frequency_without_hurting_quality() {
    let (_, _, pm, mut gen) = setup(16, 4);
    let trace: Vec<_> = (0..12).map(|_| gen.next_iteration()).collect();

    let mut every = Planner::new(PlannerConfig { replan_interval: 1, ..Default::default() });
    let mut lazy = Planner::new(PlannerConfig { replan_interval: 4, ..Default::default() });

    let mut t_every = 0.0;
    let mut t_lazy = 0.0;
    for iter in &trace {
        let w = &iter[0];
        let p1 = every.plan(w, &pm);
        let p2 = lazy.plan(w, &pm);
        t_every += pm.layer_time_overlapped(&w.route(&p1), &p1);
        t_lazy += pm.layer_time_overlapped(&w.route(&p2), &p2);
    }
    assert_eq!(every.plans_run, 12);
    assert_eq!(lazy.plans_run, 3);
    // Thanks to locality, stale placements stay close to fresh ones.
    assert!(
        t_lazy < 1.15 * t_every,
        "locality reuse degraded quality too much: {t_lazy} vs {t_every}"
    );
}

#[test]
fn planner_tracks_drifting_distributions() {
    // After a large drift, a replan must recover the win.
    let (_, _, pm, _) = setup(16, 4);
    let mut cfg = WorkloadConfig::paper_default(1, 16, 16, 16384);
    cfg.drift = 0.5; // violent drift
    let mut gen = WorkloadGen::new(cfg);
    let mut planner = Planner::new(PlannerConfig { replan_interval: 1, ..Default::default() });
    for _ in 0..10 {
        let w = &gen.next_iteration()[0];
        let p = planner.plan(w, &pm);
        let t_planned = pm.layer_time_overlapped(&w.route(&p), &p);
        let ident = Placement::identity(16, 16);
        let t_ident = pm.layer_time_overlapped(&w.route(&ident), &ident);
        assert!(t_planned <= t_ident + 1e-12);
    }
    assert_eq!(planner.plans_run, 10);
}

#[test]
fn bigger_clusters_still_converge() {
    for nodes in [1, 2, 4, 8] {
        let d = nodes * 4;
        let (_, _, pm, mut gen) = setup(d, nodes);
        let w = &gen.next_iteration()[0];
        let r = greedy_search(w, &pm, &PlannerConfig::default());
        assert!(r.evaluated <= d, "evaluated {} on {d} devices", r.evaluated);
        r.placement.validate().unwrap();
    }
}

#[test]
fn alpha_controls_aggressiveness() {
    let (_, _, pm, mut gen) = setup(16, 4);
    let w = &gen.next_iteration()[0];
    let strict = greedy_search(
        w,
        &pm,
        &PlannerConfig { alpha: 0.05, ..Default::default() },
    );
    let loose = greedy_search(
        w,
        &pm,
        &PlannerConfig { alpha: 5.0, ..Default::default() },
    );
    // A loose balance requirement stops the search earlier (or instantly).
    assert!(loose.evaluated <= strict.evaluated);
}
