//! Integration: the fault-injection timeline end to end — the no-fault
//! equivalence pin (an empty `FaultTimeline` must leave every registry
//! policy bit-for-bit identical to the frozen `simulate_policy` path),
//! graceful degradation under `DeviceDown`/`DeviceRecover` (placements
//! never touch a downed device, the session recovers, the run completes
//! without a panic), and checkpoint/resume (a killed run resumed from its
//! checkpoint reproduces the uninterrupted `SimReport` bit for bit).

use pro_prophet::balancer::{registry, BalancerSession, ProphetOptions};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::faults::FaultTimeline;
use pro_prophet::obs;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::sim::checkpoint::report_to_json;
use pro_prophet::sim::{
    simulate_policy, simulate_policy_faulted, CheckpointConfig, SimOptions, SimReport,
};
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};
use std::path::PathBuf;

fn fixed_trace(layers: usize, e: usize, d: usize, iters: usize, seed: u64) -> Trace {
    let mut cfg = WorkloadConfig::paper_default(layers, e, d, 8192);
    cfg.seed = seed;
    Trace::capture(&mut WorkloadGen::new(cfg), iters)
}

fn build(name: &str) -> Box<dyn pro_prophet::balancer::BalancingPolicy> {
    registry::build(name, &ProphetOptions::default())
        .unwrap_or_else(|| panic!("registry policy {name:?} must build"))
}

fn run_faulted(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    name: &str,
    opts: &SimOptions,
) -> Result<SimReport, String> {
    simulate_policy_faulted(model, cluster, trace, build(name), obs::noop_arc(), opts)
}

fn tmp_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("pro_prophet_faults_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

#[test]
fn empty_timeline_is_bit_identical_for_every_registry_policy() {
    // The no-fault equivalence pin: `SimOptions::default()` (empty
    // timeline, no checkpointing) must be indistinguishable from the
    // frozen trait path for every registered policy.  JSON equality
    // covers every field the checkpoint serializer round-trips
    // (iteration times, breakdowns, per-device stats, counters) at full
    // bit precision.
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2);
    let trace = fixed_trace(4, 8, 8, 4, 42);
    for name in registry::names() {
        let frozen = simulate_policy(&model, &cluster, &trace, build(name));
        let faulted =
            run_faulted(&model, &cluster, &trace, name, &SimOptions::default())
                .expect("default SimOptions cannot fail");
        assert_eq!(
            report_to_json(&frozen).to_string(),
            report_to_json(&faulted).to_string(),
            "{name}: empty fault timeline must be bit-identical"
        );
        for (i, (a, b)) in frozen.iters.iter().zip(&faulted.iters).enumerate() {
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "{name}: iter {i} time");
            assert_eq!(
                a.des_time.to_bits(),
                b.des_time.to_bits(),
                "{name}: iter {i} des_time"
            );
        }
    }
}

#[test]
fn session_survives_device_down_and_recovers() {
    // The health monitor end to end at the session level: placements
    // under a down mask never touch the downed device, the transition is
    // counter-tracked, and after recovery the session keeps serving.
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(1); // 4 devices
    let pm = PerfModel::new(&model, &cluster);
    let trace = fixed_trace(2, 8, 4, 6, 11);
    let mut session = BalancerSession::new(build("pro-prophet"), 2);

    // Healthy warmup: decisions populate the last-known-good cache.
    for layers in &trace.iterations[..2] {
        for (l, w) in layers.iter().enumerate() {
            session.decide_layer(l, w, &pm);
        }
        session.observe_iteration(layers);
    }
    assert_eq!(session.health_replans(), 0);

    // Device 2 goes down: every decision must validate under the mask.
    let down = [false, false, true, false];
    assert!(session.set_device_health(&down), "transition must be detected");
    assert_eq!(session.health_replans(), 1);
    for layers in &trace.iterations[2..4] {
        for (l, w) in layers.iter().enumerate() {
            let d = session.decide_layer(l, w, &pm);
            d.placement
                .validate_with_down(&down)
                .unwrap_or_else(|e| panic!("placement touches down device: {e}"));
        }
        session.observe_iteration(layers);
    }
    // Re-asserting the same mask is not a transition.
    assert!(!session.set_device_health(&down));
    assert_eq!(session.health_replans(), 1);

    // Recovery is a transition too (cached placements replan to use the
    // returned device again), and the session keeps serving.
    assert!(session.set_device_health(&[false; 4]));
    assert_eq!(session.health_replans(), 2);
    for layers in &trace.iterations[4..] {
        for (l, w) in layers.iter().enumerate() {
            let d = session.decide_layer(l, w, &pm);
            assert!(d.placement.n_experts() > 0);
        }
        session.observe_iteration(layers);
    }
}

#[test]
fn device_down_window_prices_des_and_bounds_are_frozen_outside() {
    // A down/recover pair on a stateless policy (deepspeed never caches,
    // so its decisions cannot leak across the window): iterations outside
    // the fault window must be bit-identical to the no-fault run, and the
    // window itself must be priced by the per-device event timeline.
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(1);
    let trace = fixed_trace(2, 8, 4, 6, 7);
    let specs = ["down dev=1 start=2", "recover dev=1 start=4"];
    let faults = FaultTimeline::parse_specs(&specs, cluster.n_devices()).unwrap();

    let baseline =
        run_faulted(&model, &cluster, &trace, "deepspeed", &SimOptions::default()).unwrap();
    let opts = SimOptions { faults, ..Default::default() };
    let faulted = run_faulted(&model, &cluster, &trace, "deepspeed", &opts).unwrap();

    assert_eq!(faulted.iters.len(), 6);
    for (i, (a, b)) in baseline.iters.iter().zip(&faulted.iters).enumerate() {
        assert!(b.time.is_finite() && b.time > 0.0, "iter {i} time must be positive");
        if (2..4).contains(&i) {
            assert_eq!(
                b.time.to_bits(),
                b.des_time.to_bits(),
                "iter {i}: fault window must be DES-priced"
            );
        } else {
            assert_eq!(
                a.time.to_bits(),
                b.time.to_bits(),
                "iter {i}: outside the window must match the no-fault run"
            );
        }
    }

    // The forecasting policy survives the same outage end to end (its
    // decisions differ across the window — here we only require a clean,
    // complete run).
    let opts2 = SimOptions {
        faults: FaultTimeline::parse_specs(&specs, cluster.n_devices()).unwrap(),
        ..Default::default()
    };
    let r = run_faulted(&model, &cluster, &trace, "pro-prophet", &opts2).unwrap();
    assert_eq!(r.iters.len(), 6);
    assert!(r.iters.iter().all(|it| it.time.is_finite() && it.time > 0.0));
}

#[test]
fn killed_run_resumed_from_checkpoint_is_bit_identical() {
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(1);
    let trace = fixed_trace(2, 8, 4, 6, 21);
    let specs = ["transient dev=2 factor=3 start=1 dur=3"];
    let faults = FaultTimeline::parse_specs(&specs, cluster.n_devices()).unwrap();
    let dir = tmp_dir("resume");

    // The "killed" run: stop after 3 of 6 iterations, checkpointing.
    let partial = run_faulted(
        &model,
        &cluster,
        &trace,
        "pro-prophet",
        &SimOptions {
            faults: faults.clone(),
            checkpoint: Some(CheckpointConfig {
                dir: dir.clone(),
                every: 2,
                resume: false,
            }),
            stop_after: Some(3),
        },
    )
    .unwrap();
    assert_eq!(partial.iters.len(), 3, "stop_after must truncate the run");

    // Resume to completion, then compare against the uninterrupted run.
    let resumed = run_faulted(
        &model,
        &cluster,
        &trace,
        "pro-prophet",
        &SimOptions {
            faults: faults.clone(),
            checkpoint: Some(CheckpointConfig { dir: dir.clone(), every: 2, resume: true }),
            stop_after: None,
        },
    )
    .unwrap();
    let straight = run_faulted(
        &model,
        &cluster,
        &trace,
        "pro-prophet",
        &SimOptions { faults, ..Default::default() },
    )
    .unwrap();

    assert_eq!(resumed.iters.len(), 6);
    assert_eq!(
        report_to_json(&resumed).to_string(),
        report_to_json(&straight).to_string(),
        "resumed run must reproduce the uninterrupted SimReport bit for bit"
    );
    for (i, (a, b)) in straight.iters.iter().zip(&resumed.iters).enumerate() {
        assert_eq!(a.time.to_bits(), b.time.to_bits(), "iter {i}: time");
        assert_eq!(a.des_time.to_bits(), b.des_time.to_bits(), "iter {i}: des_time");
        assert_eq!(
            a.forecast_error.map(f64::to_bits),
            b.forecast_error.map(f64::to_bits),
            "iter {i}: forecast_error"
        );
    }
    assert_eq!(straight.plans_run, resumed.plans_run, "planning counters");
    assert_eq!(straight.drift_replans, resumed.drift_replans, "drift counters");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_error_paths_are_reported_not_panicked() {
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(1);
    let trace = fixed_trace(2, 8, 4, 4, 5);

    // Resume from a directory with no checkpoint.
    let empty = tmp_dir("resume_missing");
    let err = run_faulted(
        &model,
        &cluster,
        &trace,
        "pro-prophet",
        &SimOptions {
            checkpoint: Some(CheckpointConfig {
                dir: empty.clone(),
                every: 1,
                resume: true,
            }),
            ..Default::default()
        },
    )
    .unwrap_err();
    assert!(err.contains("checkpoint"), "unhelpful error: {err}");

    // Resume under a different policy than the checkpoint records.
    let dir = tmp_dir("resume_mismatch");
    run_faulted(
        &model,
        &cluster,
        &trace,
        "pro-prophet",
        &SimOptions {
            checkpoint: Some(CheckpointConfig { dir: dir.clone(), every: 1, resume: false }),
            stop_after: Some(2),
            ..Default::default()
        },
    )
    .unwrap();
    let err = run_faulted(
        &model,
        &cluster,
        &trace,
        "deepspeed",
        &SimOptions {
            checkpoint: Some(CheckpointConfig { dir: dir.clone(), every: 1, resume: true }),
            ..Default::default()
        },
    )
    .unwrap_err();
    assert!(err.contains("policy"), "unhelpful error: {err}");

    let _ = std::fs::remove_dir_all(&empty);
    let _ = std::fs::remove_dir_all(&dir);
}
