//! Golden equivalence gate for the balancer refactor: the trait-based
//! driver (`sim::simulate_policy`) must reproduce the pre-refactor enum
//! path — frozen verbatim in `sim::reference` — **bit for bit**:
//! iteration times, breakdowns, per-block times, balance degrees,
//! transfer volumes, forecast errors, and all planning counters, for all
//! four original policies on fixed-seed traces.
//!
//! The `sim::Policy` migration shim is retired; this test now drives the
//! oracle directly through `reference::Policy` (the enum's final home)
//! and builds the matching trait policy by hand — the same mapping the
//! removed `From<Policy>` impl performed.
//!
//! Everything compared here is a deterministic function of the trace
//! (modeled seconds, not wall clock), so `to_bits` equality is the right
//! bar and holds across thread counts (`PRO_PROPHET_THREADS`).

use pro_prophet::balancer::{builtin, BalancingPolicy};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::moe::LoadMatrix;
use pro_prophet::planner::PlannerConfig;
use pro_prophet::prophet::PredictorKind;
use pro_prophet::sim::reference::{
    simulate_reference, single_layer_times_reference, Policy,
};
use pro_prophet::sim::{simulate_policy, single_layer_times_policy, ProphetOptions, SimReport};
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};

/// The four original policies plus the Pro-Prophet ablation arms.
fn all_policies() -> Vec<Policy> {
    vec![
        Policy::DeepspeedMoe,
        Policy::FasterMoe,
        Policy::TopK(2),
        Policy::TopK(3),
        Policy::ProProphet(ProphetOptions::full()),
        Policy::ProProphet(ProphetOptions::planner_only()),
        Policy::ProProphet(ProphetOptions::without_combination()),
    ]
}

/// The trait impl matching an oracle enum arm (the retired shim's
/// conversion, inlined here).
fn trait_policy(p: &Policy) -> Box<dyn BalancingPolicy> {
    match p {
        Policy::DeepspeedMoe => Box::new(builtin::DeepspeedMoe),
        Policy::FasterMoe => Box::new(builtin::FasterMoe::new()),
        Policy::TopK(k) => Box::new(builtin::TopK::new(*k)),
        Policy::ProProphet(o) => Box::new(builtin::ProProphet::new(o.clone())),
    }
}

fn fixed_trace(layers: usize, e: usize, d: usize, iters: usize, seed: u64) -> Trace {
    let mut cfg = WorkloadConfig::paper_default(layers, e, d, 8192);
    cfg.seed = seed;
    Trace::capture(&mut WorkloadGen::new(cfg), iters)
}

fn assert_reports_identical(oracle: &SimReport, trait_path: &SimReport, what: &str) {
    assert_eq!(oracle.policy, trait_path.policy, "{what}: policy name");
    assert_eq!(oracle.plans_run, trait_path.plans_run, "{what}: plans_run");
    assert_eq!(oracle.plans_reused, trait_path.plans_reused, "{what}: plans_reused");
    assert_eq!(oracle.drift_replans, trait_path.drift_replans, "{what}: drift_replans");
    assert_eq!(oracle.iters.len(), trait_path.iters.len(), "{what}: iteration count");
    for (i, (a, b)) in oracle.iters.iter().zip(&trait_path.iters).enumerate() {
        let it = format!("{what}: iter {i}");
        assert_eq!(a.time.to_bits(), b.time.to_bits(), "{it}: time");
        // PR 5 addition: the relaxed-vs-barrier comparison column must
        // still be the frozen barrier pricing itself for every oracle
        // policy (the oracle only ever priced the barrier model).
        assert_eq!(
            a.barrier_time.to_bits(),
            b.barrier_time.to_bits(),
            "{it}: barrier_time"
        );
        assert_eq!(
            b.barrier_time.to_bits(),
            b.time.to_bits(),
            "{it}: barrier_time must equal the frozen time on homogeneous clusters"
        );
        assert_eq!(a.trans_copies, b.trans_copies, "{it}: trans_copies");
        assert_eq!(
            a.balance_before.to_bits(),
            b.balance_before.to_bits(),
            "{it}: balance_before"
        );
        assert_eq!(
            a.balance_after.to_bits(),
            b.balance_after.to_bits(),
            "{it}: balance_after"
        );
        assert_eq!(
            a.forecast_error.map(f64::to_bits),
            b.forecast_error.map(f64::to_bits),
            "{it}: forecast_error"
        );
        assert_eq!(a.per_block_time.len(), b.per_block_time.len(), "{it}: blocks");
        for (l, (x, y)) in a.per_block_time.iter().zip(&b.per_block_time).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{it}: per_block_time[{l}]");
        }
        assert_eq!(
            a.breakdown.keys().collect::<Vec<_>>(),
            b.breakdown.keys().collect::<Vec<_>>(),
            "{it}: breakdown keys"
        );
        for (k, x) in &a.breakdown {
            assert_eq!(
                x.to_bits(),
                b.breakdown[k].to_bits(),
                "{it}: breakdown[{k}]"
            );
        }
    }
}

#[test]
fn trait_path_matches_frozen_oracle_on_paper_workload() {
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2);
    let trace = fixed_trace(4, 8, 8, 6, 42);
    for policy in all_policies() {
        let oracle = simulate_reference(&model, &cluster, &trace, &policy);
        let new = simulate_policy(&model, &cluster, &trace, trait_policy(&policy));
        assert_reports_identical(&oracle, &new, &policy.name());
    }
}

#[test]
fn trait_path_matches_oracle_across_cluster_shapes() {
    // A second (cluster, seed, size) point so the gate is not tuned to
    // one topology: 16 devices, 3 layers, k-style heavier trace.
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let cluster = ClusterSpec::hpnv(4);
    let trace = fixed_trace(3, 16, 16, 4, 7);
    for policy in all_policies() {
        let oracle = simulate_reference(&model, &cluster, &trace, &policy);
        let new = simulate_policy(&model, &cluster, &trace, trait_policy(&policy));
        assert_reports_identical(&oracle, &new, &policy.name());
    }
}

#[test]
fn drift_bookkeeping_matches_oracle_under_lazy_replanning() {
    // The drift-driven invalidation path (the subtlest duplicated loop):
    // stable regime then a violent shift, huge replan interval so ONLY
    // drift can force the second plan.  Counters must agree exactly.
    let stable = LoadMatrix::from_rows(vec![vec![600, 100, 100, 224]; 4]);
    let shifted = LoadMatrix::from_rows(vec![vec![50, 100, 100, 774]; 4]);
    let mut trace = Trace::new(1, 4, 4);
    for _ in 0..6 {
        trace.push(vec![stable.clone()]);
    }
    for _ in 0..6 {
        trace.push(vec![shifted.clone()]);
    }
    let model = ModelSpec::moe_gpt_s(4, 1, 4096);
    let cluster = ClusterSpec::hpwnv(1);
    for predictor in [PredictorKind::Auto, PredictorKind::LastValue] {
        let opts = ProphetOptions {
            planner: PlannerConfig { replan_interval: 1000, ..Default::default() },
            prophet: pro_prophet::prophet::ProphetConfig {
                predictor,
                ..Default::default()
            },
            ..Default::default()
        };
        let policy = Policy::ProProphet(opts);
        let oracle = simulate_reference(&model, &cluster, &trace, &policy);
        let new = simulate_policy(&model, &cluster, &trace, trait_policy(&policy));
        assert_reports_identical(&oracle, &new, &format!("drift/{predictor:?}"));
        assert_eq!(oracle.drift_replans, 1, "scenario sanity: one regime change");
    }
}

#[test]
fn single_layer_times_match_oracle() {
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2);
    let trace = fixed_trace(2, 8, 8, 3, 99);
    for policy in all_policies() {
        for layers in &trace.iterations {
            for w in layers {
                let (oi, op) = single_layer_times_reference(&model, &cluster, w, &policy);
                let (ni, np) =
                    single_layer_times_policy(&model, &cluster, w, trait_policy(&policy));
                assert_eq!(oi.to_bits(), ni.to_bits(), "{}: identity time", policy.name());
                assert_eq!(op.to_bits(), np.to_bits(), "{}: policy time", policy.name());
            }
        }
    }
}
