//! Integration: config files -> experiment objects -> simulation, plus the
//! example configs shipped in examples/configs/.

use pro_prophet::config::{toml, ExperimentConfig};
use pro_prophet::sim::simulate_policy;
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};

fn trace_of(exp: &ExperimentConfig, iters: usize) -> Trace {
    let mut wcfg = WorkloadConfig::paper_default(
        exp.model.n_layers,
        exp.model.n_experts,
        exp.cluster.n_devices(),
        exp.model.tokens_per_iter * exp.model.k as u64,
    );
    wcfg.seed = exp.seed;
    Trace::capture(&mut WorkloadGen::new(wcfg), iters)
}

#[test]
fn full_experiment_from_toml_runs() {
    let t = toml::parse(
        r#"
        iterations = 5
        seed = 3
        [model]
        name = "MoE-GPT-S"
        k = 2
        tokens_per_iter = 8192
        [cluster]
        kind = "hpnv"
        nodes = 2
        [planner]
        replan_interval = 2
        alpha = 0.3
        "#,
    )
    .unwrap();
    let exp = ExperimentConfig::from_table(&t).unwrap();
    assert_eq!(exp.cluster.n_devices(), 8);

    let trace = trace_of(&exp, exp.iterations);
    let r = simulate_policy(&exp.model, &exp.cluster, &trace, exp.build_policy().unwrap());
    assert_eq!(r.iters.len(), 5);
    assert!(r.avg_iter_time() > 0.0);
}

#[test]
fn policy_table_drives_simulation_end_to_end() {
    // `[policy] name = ...` picks the balancer from the registry; the
    // experiment object builds it and the simulator runs it — no enum in
    // the loop.
    let t = toml::parse(
        r#"
        iterations = 3
        [policy]
        name = "flexmoe"
        [model]
        name = "MoE-GPT-S"
        tokens_per_iter = 4096
        [cluster]
        kind = "hpwnv"
        nodes = 1
        "#,
    )
    .unwrap();
    let exp = ExperimentConfig::from_table(&t).unwrap();
    assert_eq!(exp.policy, "flexmoe");
    let trace = trace_of(&exp, exp.iterations);
    let r = simulate_policy(&exp.model, &exp.cluster, &trace, exp.build_policy().unwrap());
    assert_eq!(r.policy, "FlexMoE");
    assert_eq!(r.iters.len(), 3);
    assert!(r.avg_iter_time() > 0.0);
}

#[test]
fn shipped_example_config_parses() {
    let path = std::path::Path::new("examples/configs/fig10_hpwnv16.toml");
    if !path.exists() {
        eprintln!("SKIP: example config missing");
        return;
    }
    let exp = ExperimentConfig::from_file(path).unwrap();
    assert!(exp.cluster.n_devices() >= 8);
    assert!(exp.iterations > 0);
}

#[test]
fn shipped_straggler_config_drives_heterogeneous_sim() {
    // The straggler scenario config exercises the `[cluster]` slowdown
    // knob end to end: parse -> heterogeneous ClusterSpec -> simulation
    // whose reported time comes from the device-level event timeline.
    let path = std::path::Path::new("examples/configs/hpwnv16_straggler.toml");
    if !path.exists() {
        eprintln!("SKIP: straggler example config missing");
        return;
    }
    let exp = ExperimentConfig::from_file(path).unwrap();
    assert!(exp.cluster.is_heterogeneous(), "config must slow a device");
    assert_eq!(exp.cluster.slowdown(5), 2.5);
    assert_eq!(exp.cluster.slowdown(0), 1.0);

    let trace = trace_of(&exp, 3);
    let r = simulate_policy(&exp.model, &exp.cluster, &trace, exp.build_policy().unwrap());
    assert_eq!(r.iters.len(), 3);
    // The slowed device dominates every iteration's critical path.
    assert_eq!(r.straggler_device(), Some(5));
    for it in &r.iters {
        assert_eq!(it.straggler, 5);
        assert_eq!(it.time.to_bits(), it.des_time.to_bits(), "hetero time == DES");
    }
    // The same experiment on the homogeneous sibling cluster is strictly
    // faster.
    let mut homo = exp.clone();
    homo.cluster.device_slowdown.clear();
    let r_homo =
        simulate_policy(&homo.model, &homo.cluster, &trace, homo.build_policy().unwrap());
    assert!(
        r.avg_iter_time() > r_homo.avg_iter_time(),
        "straggler must cost time: {} !> {}",
        r.avg_iter_time(),
        r_homo.avg_iter_time()
    );
}

#[test]
fn shipped_dag_relaxed_config_simulates() {
    // `[policy] schedule = "dag_relaxed"` end to end: parse -> relaxed
    // ProphetOptions (slack-aware planner armed) -> a simulation whose
    // reported time is the relaxed DES makespan, with the barrier
    // comparison column alongside.
    let path = std::path::Path::new("examples/configs/hpwnv16_straggler_dag_relaxed.toml");
    if !path.exists() {
        eprintln!("SKIP: dag_relaxed example config missing");
        return;
    }
    let exp = ExperimentConfig::from_file(path).unwrap();
    assert_eq!(
        exp.schedule.map(|k| k.name()),
        Some("dag_relaxed"),
        "schedule key must round-trip"
    );
    let opts = exp.prophet_options();
    assert!(opts.relaxed_dag && opts.scheduler_on && opts.planner.slack_aware);
    assert!(exp.cluster.is_heterogeneous(), "config must slow a device");

    let trace = trace_of(&exp, 3);
    let r = simulate_policy(&exp.model, &exp.cluster, &trace, exp.build_policy().unwrap());
    assert_eq!(r.policy, "Pro-Prophet(dag)");
    assert_eq!(r.iters.len(), 3);
    assert_eq!(r.straggler_device(), Some(5));
    for it in &r.iters {
        assert_eq!(it.time.to_bits(), it.des_time.to_bits(), "relaxed time == DES");
        assert!(it.barrier_time > 0.0);
        let sum: f64 = it.breakdown.values().sum();
        assert!((sum - it.time).abs() < 1e-9 * it.time.max(1e-9));
    }
}

#[test]
fn shipped_transient_faults_config_simulates_end_to_end() {
    // The fault-injection scenario config exercises the `[faults]` table
    // end to end: parse -> deterministic FaultTimeline -> a simulation
    // where fault windows are DES-priced and fault-free iterations stay
    // on the frozen path.
    use pro_prophet::sim::{simulate_policy_faulted, SimOptions};
    let path = std::path::Path::new("examples/configs/hpwnv16_transient_faults.toml");
    if !path.exists() {
        eprintln!("SKIP: transient-faults example config missing");
        return;
    }
    let exp = ExperimentConfig::from_file(path).unwrap();
    let faults = exp.fault_timeline(exp.iterations);
    assert!(!faults.is_empty(), "config must inject faults");
    assert_eq!(faults.n_devices(), exp.cluster.n_devices());
    assert!(
        !exp.cluster.is_heterogeneous(),
        "faults, not a static straggler, drive this scenario"
    );

    let iters = 4;
    let trace = trace_of(&exp, iters);
    let opts = SimOptions { faults: exp.fault_timeline(iters), ..Default::default() };
    let r = simulate_policy_faulted(
        &exp.model,
        &exp.cluster,
        &trace,
        exp.build_policy().unwrap(),
        pro_prophet::obs::noop_arc(),
        &opts,
    )
    .unwrap();
    assert_eq!(r.iters.len(), iters);

    // The baseline run without the timeline: iterations before the first
    // fault activates must stay bit-identical to the fault-free path.
    let base = simulate_policy(&exp.model, &exp.cluster, &trace, exp.build_policy().unwrap());
    let mut windowed = 0;
    for i in 0..iters {
        if opts.faults.active_specs(i).is_empty() {
            if windowed == 0 {
                assert_eq!(
                    base.iters[i].time.to_bits(),
                    r.iters[i].time.to_bits(),
                    "iter {i}: before the first fault the frozen path must hold"
                );
            }
            continue;
        }
        windowed += 1;
        assert_eq!(
            r.iters[i].time.to_bits(),
            r.iters[i].des_time.to_bits(),
            "iter {i}: fault window must be DES-priced"
        );
        assert!(
            r.iters[i].time.is_finite() && r.iters[i].time > 0.0,
            "iter {i}: fault-window time must stay positive"
        );
    }
    assert!(windowed > 0, "a fault must be active within the first {iters} iterations");
}

#[test]
fn shipped_fleet_config_runs_deterministically() {
    // The mixed-tenancy fleet scenario config end to end: parse ->
    // `[fleet]` table -> two full fleet runs whose serialized reports
    // are byte-identical (the contract the fleet-smoke CI job diffs).
    use pro_prophet::faults::FaultTimeline;
    use pro_prophet::fleet::{Fleet, JobKind};
    let path = std::path::Path::new("examples/configs/fleet_mixed_train_infer.toml");
    if !path.exists() {
        eprintln!("SKIP: fleet example config missing");
        return;
    }
    let exp = ExperimentConfig::from_file(path).unwrap();
    let fleet_cfg = exp.fleet.clone().expect("config must carry a [fleet] table");
    assert_eq!(fleet_cfg.jobs.len(), 3);
    assert!(fleet_cfg.jobs.iter().any(|j| j.kind == JobKind::Infer));
    let faults = exp.fault_timeline(fleet_cfg.ticks);
    assert!(!faults.is_empty(), "config must inject the node-1 transient");

    let popts = exp.prophet_options();
    let run = |faults: &FaultTimeline| {
        Fleet::run(&fleet_cfg, &exp.cluster, &popts, faults, pro_prophet::obs::noop_arc())
            .expect("shipped fleet config must run")
    };
    let a = run(&faults);
    let b = run(&faults);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "fleet must be deterministic");

    // Scenario sanity: both training tenants finish inside the horizon,
    // the inference tenant serves traffic and reports latency.
    let alpha = a.job("alpha").expect("job alpha");
    let beta = a.job("beta").expect("job beta");
    let serve = a.job("serve").expect("job serve");
    assert!(alpha.completed_tick.is_some() && beta.completed_tick.is_some());
    assert!(serve.requests_completed > 0);
    assert!(serve.mean_latency_s > 0.0);
    assert!(a.utilization() > 0.0 && a.utilization() <= 1.0);
}

#[test]
fn custom_model_from_toml() {
    let t = toml::parse(
        r#"
        [model]
        layers = 4
        d_model = 256
        d_ff = 512
        experts = 8
        k = 1
        tokens_per_iter = 2048
        [cluster]
        kind = "lpwnv"
        nodes = 2
        "#,
    )
    .unwrap();
    let exp = ExperimentConfig::from_table(&t).unwrap();
    assert_eq!(exp.model.n_layers, 4);
    assert_eq!(exp.model.d_model, 256);
    assert_eq!(exp.model.n_experts, 8);
    assert_eq!(exp.cluster.name, "LPWNV-2");
}
