//! Integration: whole-system simulation — the paper's headline claims in
//! qualitative form (who wins, roughly by how much) across clusters,
//! models and gate widths.  Policies come from `balancer::registry` /
//! `balancer::builtin` (the `sim::Policy` enum is retired).

use pro_prophet::balancer::ProphetOptions;
use pro_prophet::benchkit::scenario::{self, trace_for as scenario_trace_for};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::speedup;
use pro_prophet::sim::SimReport;
use pro_prophet::workload::Trace;

fn trace_for(model: &ModelSpec, d: usize, iters: usize, seed: u64) -> Trace {
    scenario_trace_for(model, d, iters, seed)
}

/// Registry policy with default options (thin local names over the
/// shared `benchkit::scenario` helpers).
fn run(model: &ModelSpec, cluster: &ClusterSpec, trace: &Trace, name: &str) -> SimReport {
    scenario::report_for(name, model, cluster, trace)
}

/// Pro-Prophet family with explicit ablation options.
fn run_pp(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    trace: &Trace,
    opts: ProphetOptions,
) -> SimReport {
    scenario::report_with("pro-prophet", &opts, model, cluster, trace)
}

#[test]
fn one_routing_pass_per_layer_for_every_schedule_kind() {
    // Pricing a layer routes its load matrix exactly twice per iteration
    // — one identity sweep (the "before" balance degree) and ONE
    // placement sweep via `Engine::priced_block_styled` that feeds costs,
    // per-device vectors AND the "after" balance degree.  The DagRelaxed
    // path must ride the same single-pass pricing instead of re-routing
    // for its DAG assembly (the pattern this test pins out of existence).
    // The planner itself replays deltas on `RoutingState` and never
    // re-routes the observed matrix.
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2);
    let base = trace_for(&model, 8, 3, 37);
    for name in ["deepspeed", "pro-prophet", "pro-prophet-dag"] {
        // Fresh clone per policy: LoadMatrix clones restart their
        // routing-pass counters.
        let trace = base.clone();
        let r = run(&model, &cluster, &trace, name);
        assert_eq!(r.iters.len(), 3, "{name}");
        for (i, layers) in trace.iterations.iter().enumerate() {
            for (l, w) in layers.iter().enumerate() {
                assert_eq!(
                    w.routing_passes(),
                    2,
                    "{name}: iter {i} layer {l} must route exactly twice (identity + priced placement)"
                );
            }
        }
    }
}

#[test]
fn unchanged_placement_iterations_take_the_des_reuse_fast_path() {
    // Incremental re-pricing: on a constant trace with lazy replanning
    // the per-layer decision stabilises after iteration 1 (same cached
    // `Arc<Placement>`, plan_cost 0, same cost inputs, no fault view),
    // so iterations 2..6 must skip DES pricing entirely — observable as
    // the `sim.des_reuse` counter and exactly two `des.execute` span
    // samples — while the priced report stays byte-identical to a run
    // with reuse disabled.
    use pro_prophet::balancer::builtin::ProProphet;
    use pro_prophet::moe::LoadMatrix;
    use pro_prophet::obs::{Labels, TelemetryHub};
    use pro_prophet::planner::PlannerConfig;
    use pro_prophet::sim::{checkpoint, simulate_policy_faulted, SimOptions};
    use std::sync::Arc;

    let d = 4;
    let model = ModelSpec::moe_gpt_s(d, 1, 4096);
    let cluster = ClusterSpec::hpwnv(1);
    let mut trace = Trace::new(1, d, d);
    for _ in 0..6 {
        trace.push(vec![LoadMatrix::from_rows(vec![vec![600, 100, 100, 224]; d])]);
    }
    let opts = ProphetOptions {
        planner: PlannerConfig { replan_interval: 1000, ..Default::default() },
        ..Default::default()
    };

    let hub = Arc::new(TelemetryHub::new());
    let on = simulate_policy_faulted(
        &model,
        &cluster,
        &trace,
        Box::new(ProProphet::new(opts.clone())),
        hub.clone(),
        &SimOptions::default(),
    )
    .unwrap();
    assert_eq!(on.iters.len(), 6);
    // Iteration 0 runs the search (plan_cost > 0) and misses; iteration
    // 1 keys the cached-plan decision (plan_cost 0) and misses; 2..6 hit.
    assert_eq!(
        hub.counter_total("sim.des_reuse", Labels::None),
        4,
        "iterations 2..6 must take the re-pricing fast path"
    );
    let execute = hub.span_agg("des.execute", Labels::None).expect("execute span recorded");
    assert_eq!(execute.count, 2, "DES must run only on the two cache misses");
    // Cache hits re-emit the stored event count so the metric stream
    // keeps its per-iteration shape.
    assert!(hub.counter_total("des.events", Labels::None) > 0);

    let off = simulate_policy_faulted(
        &model,
        &cluster,
        &trace,
        Box::new(ProProphet::new(opts)),
        pro_prophet::obs::noop_arc(),
        &SimOptions { des_reuse: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(
        checkpoint::report_to_json(&on).to_string(),
        checkpoint::report_to_json(&off).to_string(),
        "disabling des_reuse must not change the priced report"
    );
}

#[test]
fn dag_relaxed_wins_extend_to_stragglers() {
    // On a straggler cluster the relaxed mode still beats doing nothing,
    // and its barrier comparison column records what the frozen model
    // would have claimed.
    let cluster = ClusterSpec::hpwnv(4).with_slowdown(3, 2.0);
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let trace = trace_for(&model, 16, 8, 41);
    let ds = run(&model, &cluster, &trace, "deepspeed");
    let dag = run(&model, &cluster, &trace, "pro-prophet-dag");
    assert!(
        dag.avg_iter_time() < ds.avg_iter_time(),
        "relaxed prophet {} !< deepspeed {} under a straggler",
        dag.avg_iter_time(),
        ds.avg_iter_time()
    );
    assert!(dag.avg_barrier_time() > 0.0);
    for it in &dag.iters {
        assert_eq!(it.time.to_bits(), it.des_time.to_bits());
    }
}

#[test]
fn headline_speedups_on_hpwnv16() {
    // Fig 10a band: Pro-Prophet 1.3-2.7x over Deepspeed-MoE, >=1x over
    // FasterMoE, on 16 GPUs with k=1.
    let cluster = ClusterSpec::hpwnv(4);
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let trace = trace_for(&model, 16, 20, 7);
    let ds = run(&model, &cluster, &trace, "deepspeed");
    let fm = run(&model, &cluster, &trace, "fastermoe");
    let pp = run_pp(&model, &cluster, &trace, ProphetOptions::full());
    let s_ds = speedup(ds.avg_iter_time(), pp.avg_iter_time());
    let s_fm = speedup(fm.avg_iter_time(), pp.avg_iter_time());
    assert!(
        (1.2..4.0).contains(&s_ds),
        "speedup vs Deepspeed-MoE out of band: {s_ds:.2}"
    );
    assert!(
        s_fm >= 1.0,
        "Pro-Prophet must not lose to FasterMoE: {s_fm:.2}"
    );
}

#[test]
fn wins_hold_across_all_five_models() {
    let cluster = ClusterSpec::hpwnv(4);
    for model in ModelSpec::table3(16, 1, 16384) {
        let trace = trace_for(&model, 16, 8, 11);
        let ds = run(&model, &cluster, &trace, "deepspeed");
        let pp = run_pp(&model, &cluster, &trace, ProphetOptions::full());
        assert!(
            pp.avg_iter_time() < ds.avg_iter_time(),
            "{}: prophet {} !< deepspeed {}",
            model.name,
            pp.avg_iter_time(),
            ds.avg_iter_time()
        );
    }
}

#[test]
fn wins_hold_for_topk_gates() {
    let cluster = ClusterSpec::hpwnv(4);
    for k in [1, 2] {
        let model = ModelSpec::moe_gpt_m(16, k, 16384);
        let trace = trace_for(&model, 16, 8, 13);
        let fm = run(&model, &cluster, &trace, "fastermoe");
        let pp = run_pp(&model, &cluster, &trace, ProphetOptions::full());
        assert!(
            pp.avg_iter_time() <= fm.avg_iter_time() * 1.001,
            "k={k}: prophet loses to FasterMoE"
        );
    }
}

#[test]
fn wins_hold_on_all_three_cluster_types() {
    for cluster in [
        ClusterSpec::hpwnv(4),
        ClusterSpec::hpnv(4),
        ClusterSpec::lpwnv(2),
    ] {
        let d = cluster.n_devices();
        let model = ModelSpec::moe_gpt_s(d, 1, 4096);
        let trace = trace_for(&model, d, 8, 17);
        let ds = run(&model, &cluster, &trace, "deepspeed");
        let pp = run_pp(&model, &cluster, &trace, ProphetOptions::full());
        assert!(
            pp.avg_iter_time() < ds.avg_iter_time(),
            "{}: no win",
            cluster.name
        );
    }
}

#[test]
fn fig14_component_ordering() {
    // baseline (no opts) >= planner-only >= full; scheduler contributes.
    let cluster = ClusterSpec::hpwnv(4);
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let trace = trace_for(&model, 16, 10, 19);
    let base = run(&model, &cluster, &trace, "deepspeed");
    let planner = run_pp(&model, &cluster, &trace, ProphetOptions::planner_only());
    let full = run_pp(&model, &cluster, &trace, ProphetOptions::full());
    assert!(planner.avg_iter_time() < base.avg_iter_time());
    assert!(full.avg_iter_time() <= planner.avg_iter_time() + 1e-12);
}

#[test]
fn fig15_planner_beats_static_topk() {
    let cluster = ClusterSpec::hpwnv(4);
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let trace = trace_for(&model, 16, 10, 23);
    let pp = run_pp(&model, &cluster, &trace, ProphetOptions::full());
    for k in [2, 3] {
        let topk = run(&model, &cluster, &trace, &format!("top{k}"));
        assert!(
            pp.avg_iter_time() < topk.avg_iter_time(),
            "planner must beat top{k}: {} vs {}",
            pp.avg_iter_time(),
            topk.avg_iter_time()
        );
    }
}

#[test]
fn prophet_iteration_times_are_stable() {
    // Fig 12: Pro-Prophet's per-iteration time is consistent (low jitter
    // relative to FasterMoE's).
    let cluster = ClusterSpec::hpwnv(4);
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let trace = trace_for(&model, 16, 30, 29);
    let pp = run_pp(&model, &cluster, &trace, ProphetOptions::full());
    let times = pp.iter_times();
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let max = times.iter().copied().fold(0.0, f64::max);
    assert!(max < 1.5 * mean, "iteration spikes: max {max} mean {mean}");
}

#[test]
fn table1_breakdown_reproduces_magnitudes() {
    // FasterMoE-style blocking LB: L.B. total 25-40%, with Search a few
    // percent and Place/Reduce roughly 10-18% each (paper Table I).
    let cluster = ClusterSpec::hpwnv(4);
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let trace = trace_for(&model, 16, 10, 31);
    let fm = run(&model, &cluster, &trace, "fastermoe");
    let lb = fm.lb_fraction();
    assert!((0.08..0.55).contains(&lb), "L.B. fraction {lb}");
    let place = fm.breakdown_fraction("place");
    let reduce = fm.breakdown_fraction("reduce");
    assert!(place > 0.0 && reduce > 0.0);
}
