//! Integration: rust PJRT runtime executing the AOT'd JAX/Pallas artifacts.
//!
//! Requires `make artifacts` (tiny preset).  These tests prove the L3<->L2
//! bridge: HLO text loads, compiles, runs, and the numerics/shapes match
//! the manifest contract.

use pro_prophet::coordinator::{extract_expert_weights, EpCluster};
use pro_prophet::moe::Placement;
use pro_prophet::runtime::{self, Runtime};
use pro_prophet::util::rng::Rng;

fn require_artifacts() -> Option<(Runtime, pro_prophet::runtime::Manifest)> {
    if !runtime::artifacts_available("tiny") {
        eprintln!("SKIP: tiny artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let man = runtime::load_manifest("tiny").expect("manifest");
    Some((rt, man))
}

#[test]
fn init_produces_full_state() {
    let Some((rt, man)) = require_artifacts() else { return };
    let init = rt.load_tagged(&man, "init").unwrap();
    let state = init.run(&[runtime::i32_scalar(7)]).unwrap();
    assert_eq!(state.len(), 3 * man.num_tensors);
    // Params match manifest shapes; moments are zero.
    for (lit, spec) in state.iter().zip(&man.tensors) {
        assert_eq!(lit.element_count(), spec.numel(), "{}", spec.name);
    }
    let m0 = runtime::to_f32_vec(&state[man.num_tensors]).unwrap();
    assert!(m0.iter().all(|&x| x == 0.0), "adam m must start at zero");
}

#[test]
fn init_is_deterministic_and_seed_dependent() {
    let Some((rt, man)) = require_artifacts() else { return };
    let init = rt.load_tagged(&man, "init").unwrap();
    let a = init.run(&[runtime::i32_scalar(3)]).unwrap();
    let b = init.run(&[runtime::i32_scalar(3)]).unwrap();
    let c = init.run(&[runtime::i32_scalar(4)]).unwrap();
    let va = runtime::to_f32_vec(&a[0]).unwrap();
    let vb = runtime::to_f32_vec(&b[0]).unwrap();
    let vc = runtime::to_f32_vec(&c[0]).unwrap();
    assert_eq!(va, vb);
    assert_ne!(va, vc);
}

#[test]
fn gate_routes_and_counts() {
    let Some((rt, man)) = require_artifacts() else { return };
    let gate = rt.load_tagged(&man, "gate").unwrap();
    let (t, d, e) = (man.tokens_per_step, man.d_model, man.n_experts);
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
    let gw: Vec<f32> = (0..d * e).map(|_| rng.normal() as f32).collect();
    let out = gate
        .run(&[
            runtime::f32_literal(&x, &[t, d]).unwrap(),
            runtime::f32_literal(&gw, &[d, e]).unwrap(),
        ])
        .unwrap();
    // gate_only returns (idx, weight, load).
    assert_eq!(out.len(), 3);
    let idx = out[0].to_vec::<i32>().unwrap();
    assert_eq!(idx.len(), t * man.k);
    assert!(idx.iter().all(|&i| (0..e as i32).contains(&i)));
    let load = runtime::to_f32_vec(&out[2]).unwrap();
    let total: f32 = load.iter().sum();
    assert_eq!(total as usize, t * man.k, "load histogram sums to T*k");
}

#[test]
fn expert_ffn_matches_host_reference() {
    let Some((rt, man)) = require_artifacts() else { return };
    let ffn = rt.load_tagged(&man, "expert_ffn").unwrap();
    let (c, d, f) = (man.capacity, man.d_model, man.d_ff);
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..c * d).map(|_| rng.normal() as f32 * 0.5).collect();
    let w1: Vec<f32> = (0..d * f).map(|_| rng.normal() as f32 * 0.1).collect();
    let b1: Vec<f32> = vec![0.05; f];
    let w2: Vec<f32> = (0..f * d).map(|_| rng.normal() as f32 * 0.1).collect();
    let b2: Vec<f32> = vec![-0.02; d];
    let out = ffn
        .run(&[
            runtime::f32_literal(&x, &[c, d]).unwrap(),
            runtime::f32_literal(&w1, &[d, f]).unwrap(),
            runtime::f32_literal(&b1, &[f]).unwrap(),
            runtime::f32_literal(&w2, &[f, d]).unwrap(),
            runtime::f32_literal(&b2, &[d]).unwrap(),
        ])
        .unwrap();
    let got = runtime::to_f32_vec(&out[0]).unwrap();
    let want = host_expert_ffn(&x, &w1, &b1, &w2, &b2, c, d, f);
    assert_eq!(got.len(), want.len());
    let mut max_err = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        max_err = max_err.max((g - w).abs());
    }
    assert!(max_err < 2e-3, "pallas-through-PJRT vs host ref: {max_err}");
}

/// Host-side oracle of the expert FFN (gelu(x@w1+b1)@w2+b2).
fn host_expert_ffn(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    c: usize,
    d: usize,
    f: usize,
) -> Vec<f32> {
    let gelu = |v: f32| {
        let v = v as f64;
        let k = (2.0 / std::f64::consts::PI).sqrt();
        (0.5 * v * (1.0 + (k * (v + 0.044715 * v * v * v)).tanh())) as f32
    };
    let mut h = vec![0.0f32; c * f];
    for i in 0..c {
        for j in 0..f {
            let mut acc = b1[j];
            for kk in 0..d {
                acc += x[i * d + kk] * w1[kk * f + j];
            }
            h[i * f + j] = gelu(acc);
        }
    }
    let mut out = vec![0.0f32; c * d];
    for i in 0..c {
        for j in 0..d {
            let mut acc = b2[j];
            for kk in 0..f {
                acc += h[i * f + kk] * w2[kk * d + j];
            }
            out[i * d + j] = acc;
        }
    }
    out
}

#[test]
fn ep_cluster_routes_and_verifies() {
    let Some((rt, man)) = require_artifacts() else { return };
    // Build real expert weights from the init artifact.
    let init = rt.load_tagged(&man, "init").unwrap();
    let state = init.run(&[runtime::i32_scalar(1)]).unwrap();
    let weights = extract_expert_weights(&man, &state, 0).unwrap();
    assert_eq!(weights.len(), man.n_experts);

    let cluster = EpCluster::new(man.clone(), weights.clone()).unwrap();
    let t = man.tokens_per_step;
    let d = man.d_model;
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32 * 0.3).collect();
    // Skewed routing: 70% of tokens to expert 0.
    let assignment: Vec<usize> = (0..t)
        .map(|i| if i % 10 < 7 { 0 } else { i % man.n_experts })
        .collect();

    let ident = Placement::identity(man.n_experts, man.n_experts);
    let r1 = cluster.run_iteration(&x, &assignment, &ident).unwrap();
    // All expert-0 tokens landed on device 0.
    let expert0_tokens = assignment.iter().filter(|&&e| e == 0).count() as u64;
    assert_eq!(r1.per_device_tokens[0], expert0_tokens);

    // Replicating expert 0 spreads its tokens across devices.
    let mut spread = Placement::identity(man.n_experts, man.n_experts);
    spread.replicate_to_all(0);
    let r2 = cluster.run_iteration(&x, &assignment, &spread).unwrap();
    assert!(
        r2.per_device_tokens[0] < r1.per_device_tokens[0],
        "replication must shed load from device 0: {:?}",
        r2.per_device_tokens
    );
    let max1 = r1.per_device_tokens.iter().max().unwrap();
    let max2 = r2.per_device_tokens.iter().max().unwrap();
    assert!(max2 < max1, "token makespan should drop: {max1} -> {max2}");

    // Outputs identical regardless of placement (routing must not change
    // numerics) — and match a direct host evaluation.
    assert_eq!(r1.output.len(), r2.output.len());
    let mut max_err = 0.0f32;
    for (a, b) in r1.output.iter().zip(&r2.output) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-4, "placement changed numerics by {max_err}");

    cluster.shutdown();
}

#[test]
fn run_rejects_bad_arity() {
    let Some((rt, man)) = require_artifacts() else { return };
    let gate = rt.load_tagged(&man, "gate").unwrap();
    let one = runtime::f32_scalar(1.0);
    assert!(gate.run(&[&one]).is_err());
}
