//! Integration: the device-level event timeline (`scheduler::dag` +
//! `sim::events`) against the frozen barrier Stage model.
//!
//! Gate 1 (equivalence): executing the barrier-shaped lowering of any
//! policy's schedule with homogeneous per-device costs must reproduce
//! `Schedule::total_time()` and `Schedule::exposed_breakdown()`
//! **bit for bit** — the DES is a strict generalization of the Stage
//! model, not a reinterpretation.
//!
//! Gate 2 (new capability): a straggler (one device slowed >= 2x via
//! `ClusterSpec::device_slowdown`) makes the DES iteration time strictly
//! exceed the homogeneous barrier estimate, the slowed device is
//! identified, and the Chrome trace grows one comp+comm lane pair per
//! device.

use pro_prophet::balancer::{registry, BalancerSession, CommStyle, ProphetOptions, ScheduleKind};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::moe::LoadMatrix;
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::scheduler::{
    build_blocking, build_blockwise, build_blockwise_dag, dag, BlockCosts, DeviceBlockCosts,
    LoadBalanceOps, Schedule,
};
use pro_prophet::sim::{events, simulate_policy, timeline, Engine};
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};

fn fixed_trace(layers: usize, e: usize, d: usize, iters: usize, seed: u64) -> Trace {
    let mut cfg = WorkloadConfig::paper_default(layers, e, d, 8192);
    cfg.seed = seed;
    Trace::capture(&mut WorkloadGen::new(cfg), iters)
}

/// Assemble one iteration's barrier schedule exactly like the simulator
/// does (decide -> price -> build by ScheduleKind).
fn schedule_for(
    session: &BalancerSession,
    eng: &Engine,
    pm: &PerfModel,
    layers: &[LoadMatrix],
) -> Schedule {
    let mut costs: Vec<BlockCosts> = Vec::with_capacity(layers.len());
    let mut kind = ScheduleKind::NoLoadBalance;
    for (l, w) in layers.iter().enumerate() {
        let d = session.decide_layer(l, w, pm);
        let coarse = d.comm_style == CommStyle::Coarse;
        costs.push(eng.block_costs_styled(w, &d.placement, d.plan_cost, coarse));
        kind = d.schedule_kind;
    }
    match kind {
        ScheduleKind::NoLoadBalance => build_blocking(&costs, LoadBalanceOps::None),
        ScheduleKind::Blocking => build_blocking(&costs, LoadBalanceOps::Blocking),
        // DagRelaxed's barrier REFERENCE is the blockwise stage form (the
        // relaxed DAG itself has no barrier schedule).
        ScheduleKind::Blockwise | ScheduleKind::DagRelaxed => build_blockwise(&costs),
    }
}

#[test]
fn des_on_barrier_dag_matches_stage_model_for_all_policies() {
    // The tentpole equivalence gate: for every built-in policy, on every
    // iteration of a fixed-seed trace, DES(barrier DAG, homogeneous
    // vectors) == Stage model, bit for bit.
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2);
    let d = cluster.n_devices();
    let pm = PerfModel::new(&model, &cluster);
    let eng = Engine::new(&cluster, &pm);
    let trace = fixed_trace(4, 8, 8, 5, 42);
    let opts = ProphetOptions::default();
    for name in ["deepspeed", "fastermoe", "top2", "top3", "pro-prophet", "planner-only"] {
        let mut session =
            BalancerSession::new(registry::build(name, &opts).unwrap(), trace.n_layers);
        for (it, layers) in trace.iterations.iter().enumerate() {
            let schedule = schedule_for(&session, &eng, &pm, layers);
            let des = events::execute(&dag::from_schedule(&schedule, d));
            assert_eq!(
                des.makespan.to_bits(),
                schedule.total_time().to_bits(),
                "{name} iter {it}: makespan"
            );
            let want = schedule.exposed_breakdown();
            assert_eq!(
                des.exposed.keys().collect::<Vec<_>>(),
                want.keys().collect::<Vec<_>>(),
                "{name} iter {it}: breakdown keys"
            );
            for (k, v) in &want {
                assert_eq!(
                    des.exposed[k].to_bits(),
                    v.to_bits(),
                    "{name} iter {it}: breakdown[{k}]"
                );
            }
            session.observe_iteration(layers);
        }
    }
}

#[test]
fn relaxed_blockwise_dag_never_slower_than_barrier_schedule() {
    // Algorithm 2 as a true-dependency DAG drops the cross-stream
    // barriers; with uniform costs every DAG edge is implied by a stage
    // barrier, so the DES can only be faster (or equal).
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let cluster = ClusterSpec::hpwnv(4);
    let pm = PerfModel::new(&model, &cluster);
    let eng = Engine::new(&cluster, &pm);
    let trace = fixed_trace(6, 16, 16, 2, 7);
    let opts = ProphetOptions::default();
    let session =
        BalancerSession::new(registry::build("pro-prophet", &opts).unwrap(), trace.n_layers);
    let layers = &trace.iterations[0];
    let mut costs: Vec<BlockCosts> = Vec::new();
    for (l, w) in layers.iter().enumerate() {
        let d = session.decide_layer(l, w, &pm);
        costs.push(eng.block_costs_styled(w, &d.placement, d.plan_cost, false));
    }
    let schedule = build_blockwise(&costs);
    let dev_costs: Vec<DeviceBlockCosts> = costs
        .iter()
        .map(|c| DeviceBlockCosts::uniform(c, cluster.n_devices()))
        .collect();
    let relaxed = build_blockwise_dag(&dev_costs, Default::default());
    relaxed.validate().unwrap();
    let des = events::execute(&relaxed);
    assert!(
        des.makespan <= schedule.total_time() + 1e-9,
        "relaxed DAG {} slower than barrier {}",
        des.makespan,
        schedule.total_time()
    );
    assert!(des.makespan > 0.0);
}

#[test]
fn dag_relaxed_breakdown_sums_and_bounded_by_barrier() {
    // The schedulable relaxed mode (PR 5): a DagRelaxed policy's reported
    // time is the DES makespan of the Algorithm-2 true-dependency DAG on
    // EVERY iteration of a homogeneous cluster, never slower than the
    // barrier reference recorded next to it, with an exposed breakdown
    // and per-block attribution that sum exactly to it.
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2);
    let trace = fixed_trace(4, 8, 8, 5, 42);
    let opts = ProphetOptions::default();
    let r = simulate_policy(
        &model,
        &cluster,
        &trace,
        registry::build("pro-prophet-dag", &opts).unwrap(),
    );
    assert_eq!(r.iters.len(), 5);
    for (i, it) in r.iters.iter().enumerate() {
        assert_eq!(it.time.to_bits(), it.des_time.to_bits(), "iter {i}: time is the DES");
        assert!(
            it.time <= it.barrier_time + 1e-9,
            "iter {i}: relaxed {} slower than barrier {}",
            it.time,
            it.barrier_time
        );
        assert!(it.time > 0.0);
        let sum: f64 = it.breakdown.values().sum();
        assert!(
            (sum - it.time).abs() < 1e-9 * it.time.max(1e-9),
            "iter {i}: breakdown sums to {sum}, time {}",
            it.time
        );
        let pb: f64 = it.per_block_time.iter().sum();
        assert!((pb - it.time).abs() < 1e-9 * it.time.max(1e-9), "iter {i}: per-block sum");
    }
}

#[test]
fn dag_relaxed_straggler_id_stable_across_iterations() {
    // Heterogeneous cluster + uniform load: the relaxed mode must keep a
    // stable straggler id (the slowed device) on every iteration, and the
    // DES-reported time must strictly exceed the homogeneous run's.
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let homo = ClusterSpec::hpwnv(4);
    let slowed_dev = 7;
    let hetero = homo.clone().with_slowdown(slowed_dev, 2.5);
    let uniform = LoadMatrix::from_rows(vec![vec![64; 16]; 16]);
    let mut trace = Trace::new(4, 16, 16);
    for _ in 0..4 {
        trace.push(vec![uniform.clone(); 4]);
    }
    let opts = ProphetOptions::default();
    let run = |cluster: &ClusterSpec| {
        simulate_policy(
            &model,
            cluster,
            &trace,
            registry::build("pro-prophet-dag", &opts).unwrap(),
        )
    };
    let r_homo = run(&homo);
    let r_het = run(&hetero);
    for (i, (a, b)) in r_homo.iters.iter().zip(&r_het.iters).enumerate() {
        assert!(
            b.time > a.time,
            "iter {i}: straggler run {} not slower than homogeneous {}",
            b.time,
            a.time
        );
        assert_eq!(b.straggler, slowed_dev, "iter {i}: straggler id must be stable");
        assert_eq!(b.time.to_bits(), b.des_time.to_bits());
        let sum: f64 = b.breakdown.values().sum();
        assert!((sum - b.time).abs() < 1e-9 * b.time.max(1e-9), "iter {i}: breakdown");
    }
    assert_eq!(r_het.straggler_device(), Some(slowed_dev));
}

#[test]
fn straggler_strictly_slower_than_homogeneous_estimate() {
    // Acceptance gate: one device slowed >= 2x makes the DES iteration
    // time strictly exceed the homogeneous barrier estimate, on every
    // iteration, and the slowed device is identified as the straggler.
    //
    // A perfectly uniform workload pins the comparison: with identical
    // per-device loads the device-level timeline has no per-device slack
    // to exploit, so the homogeneous DES equals the barrier estimate and
    // the straggler's inflation is the ONLY difference.
    let model = ModelSpec::moe_gpt_m(16, 1, 16384);
    let homo = ClusterSpec::hpwnv(4);
    let slowed_dev = 3;
    let hetero = homo.clone().with_slowdown(slowed_dev, 2.5);
    let uniform = LoadMatrix::from_rows(vec![vec![64; 16]; 16]);
    let mut trace = Trace::new(6, 16, 16);
    for _ in 0..4 {
        trace.push(vec![uniform.clone(); 6]);
    }
    let opts = ProphetOptions::default();
    let run = |cluster: &ClusterSpec| {
        simulate_policy(
            &model,
            cluster,
            &trace,
            registry::build("deepspeed", &opts).unwrap(),
        )
    };
    let r_homo = run(&homo);
    let r_het = run(&hetero);
    for (i, (a, b)) in r_homo.iters.iter().zip(&r_het.iters).enumerate() {
        assert!(
            b.time > a.time,
            "iter {i}: straggler time {} not strictly greater than homogeneous {}",
            b.time,
            a.time
        );
        assert_eq!(b.time.to_bits(), b.des_time.to_bits(), "hetero time is the DES time");
        assert_eq!(b.straggler, slowed_dev, "iter {i}: wrong straggler");
        // Everyone else idles waiting on the slow device's collectives.
        let max_other_idle = b
            .devices
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != slowed_dev)
            .map(|(_, s)| s.idle)
            .fold(0.0f64, f64::max);
        assert!(
            max_other_idle > b.devices[slowed_dev].idle,
            "iter {i}: fast devices should idle more than the straggler"
        );
    }
    assert_eq!(r_het.straggler_device(), Some(slowed_dev));
}

#[test]
fn straggler_chrome_trace_has_per_device_lanes() {
    let model = ModelSpec::moe_gpt_s(8, 1, 8192);
    let cluster = ClusterSpec::hpwnv(2).with_slowdown(6, 2.0);
    let d = cluster.n_devices();
    let trace = fixed_trace(3, 8, 8, 2, 5);
    let opts = ProphetOptions::default();
    let (op_dag, des) = pro_prophet::sim::iteration_des(
        &model,
        &cluster,
        &trace,
        registry::build("pro-prophet", &opts).unwrap(),
        1,
    )
    .unwrap();
    let j = timeline::to_chrome_trace_des(&op_dag, &des);
    let parsed = pro_prophet::util::json::parse(&j.to_string()).unwrap();
    let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // One named comp+comm lane pair per device.
    let lane_names: std::collections::BTreeSet<String> = evs
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
        .filter_map(|e| {
            e.get("args")
                .and_then(|a| a.get("name"))
                .and_then(|n| n.as_str())
                .map(str::to_string)
        })
        .collect();
    assert_eq!(lane_names.len(), 2 * d);
    assert!(lane_names.contains("dev6 comp") && lane_names.contains("dev6 comm"));
    // Ops land on more than one device lane.
    let tids: std::collections::BTreeSet<i64> = evs
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
        .map(|e| e.get("tid").unwrap().as_f64().unwrap() as i64)
        .collect();
    assert!(tids.len() > 2, "events confined to one device: {tids:?}");
}
