//! Integration: the heterogeneous candidate-mispricing fix end to end —
//! the device-aware planner (weighted evaluator + finish-time replica
//! routing) against the worst-scalar slack baseline on a straggler
//! cluster, and the per-device slowdown forecaster's decide-view
//! plumbing (inert on static clusters, off by default).

use pro_prophet::balancer::builtin::ProProphet;
use pro_prophet::balancer::ProphetOptions;
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::moe::LoadMatrix;
use pro_prophet::sim::{simulate_policy, SimReport};
use pro_prophet::workload::Trace;

/// One MoE layer, 4 devices, 4 experts (identity homes), constant across
/// iterations.  Expert 3 is globally hot (1020 tokens) but its inputs sit
/// mostly on devices 0 and 2 — and device 2 is the straggler.  BottomK
/// exclusion therefore replicates expert 3 onto {0, 2, 3}, which pins 500
/// of its tokens as LOCAL work on the straggler.  The worst-scalar
/// relaxed estimate charges every candidate the same 256x rate, sees only
/// the raw max (1020 -> 600) and accepts; the weighted estimate prices
/// device 2's projected finish (510 * 256) and keeps the identity
/// placement instead.
fn straggler_w() -> LoadMatrix {
    LoadMatrix::from_rows(vec![
        vec![100, 0, 0, 500],
        vec![0, 100, 0, 10],
        vec![0, 0, 10, 500],
        vec![0, 0, 0, 10],
    ])
}

fn constant_trace(iters: usize) -> Trace {
    let mut trace = Trace::new(1, 4, 4);
    for _ in 0..iters {
        trace.push(vec![straggler_w()]);
    }
    trace
}

/// 256x keeps every weighted product exact in f64 (powers of two) and
/// makes the mispriced compute term dominate any comm-cost difference by
/// two orders of magnitude, so the makespan comparison is robust to the
/// model's constants.
fn straggler_cluster() -> ClusterSpec {
    ClusterSpec::hpwnv(1).with_slowdowns(vec![1.0, 1.0, 256.0, 1.0])
}

fn run(opts: ProphetOptions, trace: &Trace) -> SimReport {
    let model = ModelSpec::moe_gpt_s(4, 1, 1232);
    simulate_policy(&model, &straggler_cluster(), trace, Box::new(ProProphet::new(opts)))
}

#[test]
fn device_aware_planner_beats_worst_scalar_on_straggler_cluster() {
    let trace = constant_trace(6);

    let mut dev_opts = ProphetOptions::full();
    dev_opts.planner.device_aware = true;
    dev_opts.planner.slack_aware = false;
    let mut scalar_opts = ProphetOptions::full();
    scalar_opts.planner.device_aware = false;
    scalar_opts.planner.slack_aware = true;

    let dev = run(dev_opts, &trace);
    let scalar = run(scalar_opts, &trace);
    assert_eq!(dev.iters.len(), 6);
    assert_eq!(scalar.iters.len(), 6);

    // The two estimates must disagree on the PLACEMENT, not just the
    // price: the scalar arm replicates expert 3 (moving parameter
    // copies), the weighted arm keeps identity (moving none).
    let scalar_copies: u64 = scalar.iters.iter().map(|i| i.trans_copies).sum();
    let dev_copies: u64 = dev.iters.iter().map(|i| i.trans_copies).sum();
    assert!(
        scalar_copies > 0,
        "worst-scalar arm was expected to accept the mispriced replication"
    );
    assert_eq!(
        dev_copies, 0,
        "device-aware arm was expected to keep the identity placement"
    );

    // And the disagreement must show up in executed time: the DES prices
    // both arms on the TRUE cluster, where the replication the scalar
    // estimate accepted runs 510 tokens on the 256x straggler while
    // identity runs only 10 there.
    for (i, (a, b)) in dev.iters.iter().zip(&scalar.iters).enumerate() {
        assert!(
            a.time < b.time,
            "iter {i}: device-aware {} !< worst-scalar {}",
            a.time,
            b.time
        );
    }
    assert!(dev.total_time() < scalar.total_time());
}

#[test]
fn device_forecast_plumbing_is_inert_on_static_clusters() {
    // Arming the per-device forecaster substitutes the forecast vector
    // into the planner's decide view.  On a cluster whose slowdowns
    // never change, the realized vector the forecaster learns IS the
    // static vector — 256.0 and 1.0 round-trip the fixed-point encoding
    // exactly — so every decision, placement, and priced time must be
    // bit-identical to the unarmed run (iteration 1 decides pre-forecast
    // on the static model in both arms).
    let trace = constant_trace(5);

    let off = run(ProphetOptions::full(), &trace);
    let mut armed_opts = ProphetOptions::full();
    armed_opts.prophet.device_forecast = true;
    let armed = run(armed_opts, &trace);

    assert_eq!(armed.iters.len(), off.iters.len());
    assert_eq!(armed.plans_run, off.plans_run);
    for (i, (a, b)) in armed.iters.iter().zip(&off.iters).enumerate() {
        assert_eq!(
            a.time.to_bits(),
            b.time.to_bits(),
            "iter {i}: armed {} vs unarmed {}",
            a.time,
            b.time
        );
        assert_eq!(a.barrier_time.to_bits(), b.barrier_time.to_bits(), "iter {i}");
        assert_eq!(a.trans_copies, b.trans_copies, "iter {i}");
    }
}
