//! Integration: the fleet layer end to end — the degenerate-fleet oracle
//! (a one-job fleet holding the whole cluster reproduces
//! `simulate_policy`'s `SimReport` bit for bit), the lease-disjointness
//! invariant under churny mixed-tenancy scenarios, byte-identical
//! determinism of the full `FleetReport`, parking on a total outage, and
//! admission backpressure under `max_concurrent` / capacity limits.

use pro_prophet::balancer::{registry, ProphetOptions};
use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::faults::FaultTimeline;
use pro_prophet::fleet::{AdmissionPolicy, Fleet, FleetConfig, FleetReport, JobKind, JobSpec};
use pro_prophet::obs;
use pro_prophet::sim::checkpoint::report_to_json;
use pro_prophet::sim::simulate_policy;
use pro_prophet::workload::{Trace, WorkloadConfig, WorkloadGen};

fn job(spec: &str) -> JobSpec {
    JobSpec::parse(spec).unwrap_or_else(|e| panic!("job spec `{spec}` must parse: {e}"))
}

fn cfg(jobs: Vec<JobSpec>, ticks: usize) -> FleetConfig {
    FleetConfig {
        ticks,
        tick_s: 0.25,
        max_concurrent: jobs.len().max(1),
        admission: AdmissionPolicy::Fifo,
        rebalance_interval: 4,
        migration_budget: 1,
        jobs,
    }
}

fn run(cfg: &FleetConfig, cluster: &ClusterSpec, faults: &FaultTimeline) -> FleetReport {
    Fleet::run(cfg, cluster, &ProphetOptions::default(), faults, obs::noop_arc())
        .expect("fleet run must succeed")
}

#[test]
fn degenerate_fleet_reproduces_simulate_policy_bit_for_bit() {
    // The oracle the fleet's pricing path is pinned to: one training job
    // leasing the WHOLE cluster, one iteration per tick, no faults, no
    // rebalancing pressure (train leases are rigid).  `sub_cluster` on a
    // full lease is a verbatim clone and `price_and_observe` is shared
    // with `simulate_policy_opts`, so the embedded per-job `SimReport`
    // must match the single-job simulator at full bit precision —
    // including per-device DES stats and policy counters.
    let cluster = ClusterSpec::hpwnv(2);
    let d = cluster.n_devices();
    for policy in ["pro-prophet", "deepspeed", "fastermoe"] {
        let fleet_cfg = cfg(
            vec![job(&format!(
                "train name=solo nodes=2 model=s k=1 tokens=8192 iters=6 policy={policy} seed=17"
            ))],
            8,
        );
        let report = run(&fleet_cfg, &cluster, &FaultTimeline::empty());
        let fleet_sim = &report.jobs[0].sim;

        // The oracle run, built with the same conventions the fleet's
        // JobRuntime uses (experts per layer == device count, workload
        // seeded from the job spec).
        let model = ModelSpec::by_name("s", d, 1, 8192).expect("model s must exist");
        let mut wcfg = WorkloadConfig::paper_default(model.n_layers, d, d, 8192);
        wcfg.seed = 17;
        let trace = Trace::capture(&mut WorkloadGen::new(wcfg), 6);
        let oracle = simulate_policy(
            &model,
            &cluster,
            &trace,
            registry::build(policy, &ProphetOptions::default()).expect("registry policy"),
        );

        assert_eq!(
            report_to_json(fleet_sim).to_string(),
            report_to_json(&oracle).to_string(),
            "degenerate fleet diverged from simulate_policy under {policy}"
        );
        assert_eq!(report.jobs[0].iterations, 6);
        assert_eq!(report.jobs[0].completed_tick, Some(5));
    }
}

#[test]
fn no_node_is_ever_leased_to_two_jobs() {
    // Lease disjointness stepped tick by tick through a deliberately
    // churny scenario: staggered starts, completions freeing nodes
    // mid-run, smallest-first admission reordering the queue, and an
    // elastic inference tenant the rebalancer grows and shrinks.
    let cluster = ClusterSpec::hpwnv(4);
    let mut fleet_cfg = cfg(
        vec![
            job("train name=a nodes=2 model=s iters=6 policy=deepspeed seed=1"),
            job("train name=b nodes=2 model=s iters=5 start=1 policy=deepspeed seed=2"),
            job("infer name=q nodes=1 min_nodes=1 max_nodes=2 model=s rate=40 burst_on=3 burst_off=3 burst_factor=4 batch_tokens=512 policy=deepspeed seed=3"),
            job("train name=c nodes=2 model=s iters=4 start=2 policy=deepspeed seed=4"),
        ],
        24,
    );
    fleet_cfg.admission = AdmissionPolicy::SmallestFirst;
    fleet_cfg.rebalance_interval = 2;
    fleet_cfg.migration_budget = 2;

    let mut fleet = Fleet::new(
        &fleet_cfg,
        &cluster,
        &ProphetOptions::default(),
        &FaultTimeline::empty(),
        obs::noop_arc(),
    )
    .expect("fleet must build");
    for _ in 0..fleet_cfg.ticks {
        fleet.step().expect("step must succeed");
        let leases = fleet.leases();
        let mut seen = std::collections::BTreeSet::new();
        for (jid, nodes) in &leases {
            assert!(!nodes.is_empty(), "job {jid} is running with an empty lease");
            for &n in nodes {
                assert!(n < cluster.n_nodes, "node {n} out of range");
                assert!(
                    seen.insert(n),
                    "node {n} leased twice at tick {} (leases: {leases:?})",
                    fleet.current_tick()
                );
            }
        }
    }
    let report = fleet.into_report();
    // Everything that could finish did; the scenario actually exercised
    // churn (b and c queue behind a full cluster until leases free up).
    assert!(report.jobs.iter().all(|j| j.admitted_tick.is_some()));
    assert!(report.counters.deferred_admissions > 0);
    assert!(
        report
            .jobs
            .iter()
            .filter(|j| j.kind == JobKind::Train)
            .all(|j| j.completed_tick.is_some()),
        "all training jobs should complete within the horizon"
    );
}

#[test]
fn same_seed_and_config_produce_byte_identical_reports() {
    // Full-report determinism over the richest mix the layer supports:
    // faults + bursty arrivals + smallest-first admission + rebalancing.
    let cluster = ClusterSpec::hpwnv(3);
    let faults = FaultTimeline::parse_specs(
        &["transient dev=2 factor=6 start=3 dur=4", "down dev=9 start=8", "recover dev=9 start=12"],
        cluster.n_devices(),
    )
    .expect("fault specs must parse");
    let mut fleet_cfg = cfg(
        vec![
            job("train name=t nodes=2 model=s iters=10 policy=pro-prophet seed=5"),
            job("infer name=i nodes=1 min_nodes=1 max_nodes=2 model=s rate=8 burst_on=2 burst_off=4 burst_factor=5 batch_tokens=768 policy=fastermoe seed=6"),
        ],
        16,
    );
    fleet_cfg.admission = AdmissionPolicy::SmallestFirst;

    let a = run(&fleet_cfg, &cluster, &faults);
    let b = run(&fleet_cfg, &cluster, &faults);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "same seed + same config must be byte-identical"
    );
}

#[test]
fn total_outage_parks_the_fleet_and_it_recovers() {
    // Every device down: the fleet parks affected tenants (no panic, no
    // progress) and resumes exactly where it left off once devices
    // recover — the run just finishes later.
    let cluster = ClusterSpec::hpwnv(1);
    let d = cluster.n_devices();
    let mut specs = Vec::new();
    for dev in 0..d {
        specs.push(format!("down dev={dev} start=2"));
        specs.push(format!("recover dev={dev} start=5"));
    }
    let faults =
        FaultTimeline::parse_specs(&specs, d).expect("outage specs must parse");
    let fleet_cfg = cfg(
        vec![job("train name=only nodes=1 model=s iters=5 policy=deepspeed seed=9")],
        12,
    );
    let report = run(&fleet_cfg, &cluster, &faults);
    let j = &report.jobs[0];
    // Iterations at ticks 0,1 then parked 2,3,4 then 5,6,7 finish it.
    assert_eq!(j.parked_ticks, 3);
    assert_eq!(j.iterations, 5);
    assert_eq!(j.completed_tick, Some(7));
    assert_eq!(report.counters.parked_ticks, 3);

    // The clean-prefix pin: iterations priced before the outage match a
    // fault-free fleet bit for bit (parking must not perturb state).
    let clean = run(&fleet_cfg, &cluster, &FaultTimeline::empty());
    for i in 0..2 {
        assert_eq!(
            j.sim.iters[i].time, clean.jobs[0].sim.iters[i].time,
            "pre-outage iteration {i} should be untouched by the timeline"
        );
    }
}

#[test]
fn admission_backpressure_respects_caps_and_eventually_drains() {
    // Three one-node jobs, a one-tenant concurrency cap: strictly serial
    // execution, deferred admissions counted, everything completes.
    let cluster = ClusterSpec::hpwnv(2);
    let mut fleet_cfg = cfg(
        vec![
            job("train name=j0 nodes=1 model=s iters=3 policy=deepspeed seed=1"),
            job("train name=j1 nodes=1 model=s iters=3 policy=deepspeed seed=2"),
            job("train name=j2 nodes=1 model=s iters=3 policy=deepspeed seed=3"),
        ],
        16,
    );
    fleet_cfg.max_concurrent = 1;

    let mut fleet = Fleet::new(
        &fleet_cfg,
        &cluster,
        &ProphetOptions::default(),
        &FaultTimeline::empty(),
        obs::noop_arc(),
    )
    .expect("fleet must build");
    for _ in 0..fleet_cfg.ticks {
        fleet.step().expect("step must succeed");
        assert!(fleet.leases().len() <= 1, "max_concurrent=1 must cap running tenants");
    }
    let report = fleet.into_report();
    assert!(report.jobs.iter().all(|j| j.completed_tick.is_some()));
    assert!(report.counters.deferred_admissions > 0);
    // Serial: j0 runs ticks 0-2, j1 3-5, j2 6-8.
    assert_eq!(report.jobs[0].completed_tick, Some(2));
    assert_eq!(report.jobs[1].admitted_tick, Some(3));
    assert_eq!(report.jobs[1].completed_tick, Some(5));
    assert_eq!(report.jobs[2].admitted_tick, Some(6));
    assert_eq!(report.jobs[2].completed_tick, Some(8));
}
