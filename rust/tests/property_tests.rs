//! Property-based tests over the coordinator invariants (routing,
//! placement, planning, scheduling, serialization) using the in-repo
//! seeded-random harness (rust/src/util/prop.rs; proptest is unavailable
//! offline).  Replay a failure with PROP_SEED=<seed> PROP_CASES=1.

use pro_prophet::cluster::ClusterSpec;
use pro_prophet::config::ModelSpec;
use pro_prophet::metrics::balance_degree;
use pro_prophet::moe::{LoadMatrix, Placement, RoutingState};
use pro_prophet::perfmodel::PerfModel;
use pro_prophet::planner::{
    greedy_search, greedy_search_reference, locality, policies, PlannerConfig,
};
use pro_prophet::scheduler::blockwise::SplitMode;
use pro_prophet::scheduler::{
    build_blocking, build_blockwise, build_blockwise_dag, dag, relaxed_makespan_bound,
    BlockCosts, DeviceBlockCosts, LoadBalanceOps, Op, OpDag, Stream,
};
use pro_prophet::sim::{dag_from_schedule_with_costs, events, Engine};
use pro_prophet::util::prop::{self, Cases};
use pro_prophet::util::rng::Rng;
use pro_prophet::workload::Trace;

/// Random load matrix with random size and skew.
fn random_w(rng: &mut Rng) -> LoadMatrix {
    let d = [4usize, 8, 16][rng.below(3)];
    let per_device = 64 + rng.below(2048) as u64;
    let skew = 0.15 + rng.f64();
    let rows: Vec<Vec<u64>> = (0..d)
        .map(|_| prop::random_histogram(rng, d, per_device, skew))
        .collect();
    LoadMatrix::from_rows(rows)
}

fn random_placement(rng: &mut Rng, e: usize, d: usize) -> Placement {
    let mut p = Placement::identity(e, d);
    let extra = rng.below(e + 1);
    for _ in 0..extra {
        let expert = rng.below(e);
        match rng.below(3) {
            0 => p.replicate_to_all(expert),
            1 => p.add_replica(expert, rng.below(d)),
            _ => {
                let excl: Vec<usize> = (0..rng.below(d)).map(|_| rng.below(d)).collect();
                p.replicate_except(expert, &excl);
            }
        }
    }
    p
}

fn pm_for(d: usize) -> PerfModel {
    PerfModel::new(
        &ModelSpec::moe_gpt_s(d, 1, 4096 * d as u64),
        &ClusterSpec::hpwnv(d.div_ceil(4)),
    )
}

#[test]
fn prop_routing_conserves_tokens() {
    Cases::default().run(|rng| {
        let w = random_w(rng);
        let p = random_placement(rng, w.n_experts(), w.n_devices());
        let routed = w.route(&p);
        assert_eq!(
            routed.h.iter().sum::<u64>(),
            w.total_tokens(),
            "tokens lost in routing"
        );
        // Received <= computed per device minus local contribution bound.
        assert!(routed.r.iter().sum::<u64>() <= w.total_tokens());
        assert_eq!(
            routed.sent.iter().sum::<u64>(),
            routed.r.iter().sum::<u64>(),
            "sent != received"
        );
    });
}

#[test]
fn prop_traffic_matrix_consistent_with_routed() {
    Cases::default().run(|rng| {
        let w = random_w(rng);
        let p = random_placement(rng, w.n_experts(), w.n_devices());
        let routed = w.route(&p);
        let traffic = w.traffic(&p);
        for i in 0..w.n_devices() {
            let ingress: u64 = (0..w.n_devices()).map(|j| traffic[j][i]).sum();
            assert_eq!(ingress, routed.r[i], "device {i} ingress mismatch");
            let egress: u64 = (0..w.n_devices()).map(|j| traffic[i][j]).sum();
            assert_eq!(egress, routed.sent[i], "device {i} egress mismatch");
            assert_eq!(traffic[i][i], 0, "self-traffic");
        }
    });
}

#[test]
fn prop_full_replication_kills_all_traffic() {
    Cases::default().run(|rng| {
        let w = random_w(rng);
        let mut p = Placement::identity(w.n_experts(), w.n_devices());
        for e in 0..w.n_experts() {
            p.replicate_to_all(e);
        }
        let routed = w.route(&p);
        assert_eq!(routed.r.iter().sum::<u64>(), 0);
        // Each device computes exactly its own tokens.
        for d in 0..w.n_devices() {
            assert_eq!(routed.h[d], w.device_tokens(d));
        }
    });
}

#[test]
fn prop_routing_state_matches_full_route() {
    // Equivalence gate of the incremental router: after ANY sequence of
    // apply/undo deltas, the replayed RoutedLoad is bit-identical to a
    // fresh route() of the same placement.
    Cases::default().run(|rng| {
        let w = random_w(rng);
        let (e, d) = (w.n_experts(), w.n_devices());
        let mut rs = RoutingState::new();
        rs.init(&w);
        for _ in 0..(2 + rng.below(2 * e)) {
            // Mostly applies, sometimes an undo in the middle.
            if rs.depth() > 0 && rng.below(4) == 0 {
                rs.undo(&w);
            } else {
                let expert = rng.below(e);
                match rng.below(3) {
                    0 => rs.apply_replicate_to_all(&w, expert),
                    1 => rs.apply_add_replica(&w, expert, rng.below(d)),
                    _ => {
                        let excl: Vec<usize> =
                            (0..rng.below(d)).map(|_| rng.below(d)).collect();
                        rs.apply_replicate_except(&w, expert, &excl);
                    }
                }
            }
            rs.evaluate();
            let incremental = rs.to_routed_load();
            let full = w.route(rs.placement());
            assert_eq!(incremental, full, "diverged at depth {}", rs.depth());
        }
        // Unwinding everything restores the identity route exactly.
        while rs.depth() > 0 {
            rs.undo(&w);
        }
        rs.evaluate();
        assert!(rs.placement().is_identity());
        assert_eq!(rs.to_routed_load(), w.route_identity());
    });
}

#[test]
fn prop_greedy_matches_reference() {
    // The incremental-router greedy search must reproduce the reference
    // (full re-route) implementation exactly: same placement, same
    // selection order, and bit-identical time estimates.
    Cases::new(64).run(|rng| {
        let w = random_w(rng);
        let pm = pm_for(w.n_devices());
        let cfg = PlannerConfig {
            alpha: 0.05 + rng.f64(),
            n_exclude: if rng.below(2) == 0 {
                pro_prophet::planner::AUTO_EXCLUDE
            } else {
                rng.below(w.n_devices())
            },
            use_overlap_model: rng.below(2) == 0,
            // On homogeneous clusters the slack-aware relaxed estimate is
            // bit-identical to the Eq-8 model, so randomizing this flag
            // must never diverge from the frozen reference.
            slack_aware: rng.below(2) == 0,
            // Same contract for the device-aware path: its gate is
            // `pm.is_heterogeneous()`, so on these homogeneous clusters
            // the weighted evaluator must never even be invoked.
            device_aware: rng.below(2) == 0,
            ..Default::default()
        };
        let new = greedy_search(&w, &pm, &cfg);
        let reference = greedy_search_reference(&w, &pm, &cfg);
        assert_eq!(new.placement, reference.placement, "placements diverged");
        assert_eq!(new.selected, reference.selected, "selection order diverged");
        assert_eq!(new.evaluated, reference.evaluated, "candidate counts diverged");
        assert_eq!(
            new.t_est.to_bits(),
            reference.t_est.to_bits(),
            "t_est diverged: {} vs {}",
            new.t_est,
            reference.t_est
        );
        assert_eq!(
            new.t_identity.to_bits(),
            reference.t_identity.to_bits(),
            "t_identity diverged"
        );
    });
}

#[test]
fn prop_device_aware_matches_slack_on_uniform_slowdown() {
    // A uniformly slowed cluster (every device at factor u >= 1) is
    // heterogeneous to the gate but carries no ranking information, so
    // the dev-aware search must collapse onto the worst-scalar slack
    // path bit for bit: u = k/2 keeps every product (H_d + tokens)·u the
    // weighted scans compare exact in f64 (H·k stays far below 2^53), so
    // strict inequalities and ties survive the multiplication — every
    // replica target, heaviest-device pick, and Eq-7 stop is identical —
    // and the weighted price computes t_fec from fl(max_h·u), the same
    // expression layer_time_sn_relaxed evaluates (max_slowdown() of a
    // uniform vector is u for u >= 1).
    Cases::new(48).run(|rng| {
        let w = random_w(rng);
        let d = w.n_devices();
        let u = [1.5, 2.0, 2.5, 3.0][rng.below(4)];
        let cluster = ClusterSpec::hpwnv(d.div_ceil(4)).with_slowdowns(vec![u; d]);
        let pm = PerfModel::new(&ModelSpec::moe_gpt_s(d, 1, 4096 * d as u64), &cluster);
        assert!(pm.is_heterogeneous());
        let alpha = 0.05 + rng.f64();
        let n_exclude = if rng.below(2) == 0 {
            pro_prophet::planner::AUTO_EXCLUDE
        } else {
            rng.below(d)
        };
        let dev_cfg = PlannerConfig {
            alpha,
            n_exclude,
            use_overlap_model: true,
            device_aware: true,
            slack_aware: false,
            ..Default::default()
        };
        let scalar_cfg = PlannerConfig {
            alpha,
            n_exclude,
            use_overlap_model: true,
            device_aware: false,
            slack_aware: true,
            ..Default::default()
        };
        let dev = greedy_search(&w, &pm, &dev_cfg);
        let scalar = greedy_search(&w, &pm, &scalar_cfg);
        assert_eq!(dev.placement, scalar.placement, "placements diverged (u={u})");
        assert_eq!(dev.selected, scalar.selected, "selection order diverged (u={u})");
        assert_eq!(dev.evaluated, scalar.evaluated, "candidate counts diverged (u={u})");
        assert_eq!(
            dev.t_est.to_bits(),
            scalar.t_est.to_bits(),
            "t_est diverged: {} vs {} (u={u})",
            dev.t_est,
            scalar.t_est
        );
        assert_eq!(
            dev.t_identity.to_bits(),
            scalar.t_identity.to_bits(),
            "t_identity diverged (u={u})"
        );
    });
}

#[test]
fn prop_device_forecaster_exact_on_constant_slowdowns() {
    // Any slowdown the config surface can express (<= 6 decimal places,
    // floored at 1e-3) survives the forecaster's fixed-point encoding:
    // a constant vector forecasts back exactly for LastValue after one
    // observation, and to within fixed-point resolution for every
    // predictor kind after a few.
    use pro_prophet::prophet::{DeviceForecaster, PredictorKind, ProphetConfig};
    Cases::default().run(|rng| {
        let d = 1 + rng.below(16);
        let v: Vec<f64> = (0..d)
            .map(|_| (1_000 + rng.below(9_999_000)) as f64 / 1e6)
            .collect();
        let kind = [
            PredictorKind::Auto,
            PredictorKind::LastValue,
            PredictorKind::Ema,
            PredictorKind::WindowMean,
            PredictorKind::LinearTrend,
        ][rng.below(5)];
        let mut f =
            DeviceForecaster::new(&ProphetConfig { predictor: kind, ..Default::default() }, d);
        assert!(f.forecast().is_none());
        for _ in 0..(2 + rng.below(6)) {
            let _ = f.observe(&v);
        }
        for (g, want) in f.forecast().unwrap().iter().zip(&v) {
            assert!((g - want).abs() < 1e-6, "{kind:?}: {g} vs {want}");
        }
        let mut last = DeviceForecaster::new(
            &ProphetConfig { predictor: PredictorKind::LastValue, ..Default::default() },
            d,
        );
        let _ = last.observe(&v);
        for (g, want) in last.forecast().unwrap().iter().zip(&v) {
            assert_eq!(g.to_bits(), want.to_bits(), "LastValue roundtrip: {g} vs {want}");
        }
    });
}

#[test]
fn prop_greedy_never_worse_and_valid() {
    Cases::new(64).run(|rng| {
        let w = random_w(rng);
        let pm = pm_for(w.n_devices());
        let cfg = PlannerConfig {
            alpha: 0.05 + rng.f64(),
            n_exclude: if rng.below(2) == 0 {
                pro_prophet::planner::AUTO_EXCLUDE
            } else {
                rng.below(w.n_devices())
            },
            use_overlap_model: rng.below(2) == 0,
            ..Default::default()
        };
        let r = greedy_search(&w, &pm, &cfg);
        assert!(r.t_est <= r.t_identity + 1e-12);
        r.placement.validate().unwrap();
        assert!(r.evaluated <= w.n_experts());
        // The returned estimate is reproducible from the placement.
        let routed = w.route(&r.placement);
        let t = pm.layer_time_sn(
            &routed,
            r.selected.len(),
            match cfg.n_exclude {
                pro_prophet::planner::AUTO_EXCLUDE => w.n_devices() / 2,
                n => n.min(w.n_devices() - 1),
            },
            cfg.use_overlap_model,
        );
        assert!((t - r.t_est).abs() <= 1e-9 * t.max(1.0) + 1e-12);
    });
}

#[test]
fn prop_greedy_balances_dominant_expert_workloads() {
    // On the paper's motivating pattern — one expert dominating the layer
    // (Fig 3) — the planner must strictly improve both balance degree and
    // makespan.  (On arbitrary random inputs only the modeled-time
    // invariant holds; see prop_greedy_never_worse_and_valid.)
    Cases::new(64).run(|rng| {
        let d = [4usize, 8, 16][rng.below(3)];
        let hot = rng.below(d);
        let per_device = 256 + rng.below(2048) as u64;
        let rows: Vec<Vec<u64>> = (0..d)
            .map(|_| {
                let mut row = prop::random_histogram(rng, d, per_device, 2.0);
                // Concentrate >=60% of each device's tokens on the hot expert.
                let boost: u64 = row.iter().sum::<u64>() * 2;
                row[hot] += boost;
                row
            })
            .collect();
        let w = LoadMatrix::from_rows(rows);
        let pm = pm_for(d);
        let r = greedy_search(&w, &pm, &PlannerConfig::default());
        assert!(!r.placement.is_identity(), "must act on a dominant expert");
        assert!(r.selected.contains(&hot), "must select the hot expert");
        let before = w.route_identity();
        let after = w.route(&r.placement);
        assert!(after.max_h() < before.max_h(), "makespan must drop");
        assert!(
            balance_degree(&after.h) < balance_degree(&before.h),
            "balance must improve on a dominant-expert load"
        );
    });
}

#[test]
fn prop_fastermoe_never_worse_than_identity_in_model_terms() {
    Cases::new(64).run(|rng| {
        let w = random_w(rng);
        let pm = pm_for(w.n_devices());
        let p = policies::fastermoe_shadowing(&w, &pm);
        let ident = Placement::identity(w.n_experts(), w.n_devices());
        let t_p = pm.layer_time_blocking(&w.route(&p), &p);
        let t_i = pm.layer_time_blocking(&w.route(&ident), &ident);
        assert!(t_p <= t_i + 1e-12);
    });
}

#[test]
fn prop_engine_costs_nonnegative_and_monotone() {
    Cases::new(64).run(|rng| {
        let w = random_w(rng);
        let d = w.n_devices();
        let model = ModelSpec::moe_gpt_s(d, 1, 4096 * d as u64);
        let cluster = ClusterSpec::hpwnv(d.div_ceil(4));
        let pm = PerfModel::new(&model, &cluster);
        let eng = Engine::new(&cluster, &pm);
        let p = random_placement(rng, d, d);
        let c = eng.block_costs(&w, &p, 0.0);
        for v in [c.a2a, c.fec, c.bec, c.fnec, c.bnec, c.trans, c.agg] {
            assert!(v >= 0.0 && v.is_finite());
        }
        // Adding a replica never increases A2A (strictly decreases it when
        // the replica actually absorbs traffic).
        let mut p2 = p.clone();
        p2.replicate_to_all(rng.below(d));
        let c2 = eng.block_costs(&w, &p2, 0.0);
        assert!(c2.a2a <= c.a2a + 1e-12);
    });
}

#[test]
fn prop_blockwise_bounded_by_blocking_and_lower_bound() {
    Cases::default().run(|rng| {
        let n_blocks = 1 + rng.below(24);
        let blocks: Vec<BlockCosts> = (0..n_blocks)
            .map(|_| BlockCosts {
                a2a: rng.f64() * 0.01,
                fec: rng.f64() * 0.01,
                bec: rng.f64() * 0.02,
                fnec: rng.f64() * 0.01,
                bnec: rng.f64() * 0.02,
                trans: rng.f64() * 0.02,
                agg: rng.f64() * 0.02,
                plan: rng.f64() * 0.001,
            })
            .collect();
        let blocking = build_blocking(&blocks, LoadBalanceOps::Blocking);
        let overlapped = build_blockwise(&blocks);
        assert!(overlapped.total_time() <= blocking.total_time() + 1e-12);
        let lower: f64 = blocks
            .iter()
            .map(|c| 4.0 * c.a2a + c.fec + c.bec + c.fnec + c.bnec)
            .sum();
        assert!(overlapped.total_time() >= lower - 1e-9);
        overlapped.validate_dependencies().unwrap();
        // Total Trans+Agg volume is conserved across the two schedules
        // (the scheduler moves work, never drops it).
        let vol = |s: &pro_prophet::scheduler::Schedule| -> f64 {
            s.stages
                .iter()
                .flat_map(|st| st.comm.iter())
                .filter(|o| o.op.is_load_balancing())
                .map(|o| o.dur)
                .sum()
        };
        assert!((vol(&blocking) - vol(&overlapped)).abs() < 1e-9);
    });
}

fn random_block_costs(rng: &mut Rng) -> BlockCosts {
    BlockCosts {
        a2a: rng.f64() * 0.01,
        fec: rng.f64() * 0.01,
        bec: rng.f64() * 0.02,
        fnec: rng.f64() * 0.01,
        bnec: rng.f64() * 0.02,
        trans: rng.f64() * 0.02,
        agg: rng.f64() * 0.02,
        plan: rng.f64() * 0.001,
    }
}

fn random_device_costs(rng: &mut Rng, d: usize) -> DeviceBlockCosts {
    let v = |rng: &mut Rng, scale: f64| -> Vec<f64> {
        (0..d).map(|_| rng.f64() * scale).collect()
    };
    DeviceBlockCosts {
        a2a: v(rng, 0.01),
        fec: v(rng, 0.01),
        bec: v(rng, 0.02),
        fnec: v(rng, 0.01),
        bnec: v(rng, 0.02),
        trans: v(rng, 0.02),
        agg: v(rng, 0.02),
        plan: v(rng, 0.001),
    }
}

#[test]
fn prop_blockwise_dag_acyclic_and_causal() {
    // Generated Algorithm-2 DAGs are acyclic (validate() proves dep
    // edges only point backwards) and the executed timeline is causal:
    // no op starts before its dependencies finish — device-locally for
    // compute, across ALL devices for collectives.
    Cases::new(64).run(|rng| {
        let d = 2 + rng.below(7);
        let n_blocks = 1 + rng.below(6);
        let blocks: Vec<DeviceBlockCosts> =
            (0..n_blocks).map(|_| random_device_costs(rng, d)).collect();
        let mode = [SplitMode::Split, SplitMode::ExpertOnly, SplitMode::NonExpertOnly]
            [rng.below(3)];
        let des_dag = build_blockwise_dag(&blocks, mode);
        des_dag.validate().unwrap();
        let des = events::execute(&des_dag);
        for i in 0..des_dag.len() {
            let op = des_dag.op(i);
            let dur = des_dag.dur(i);
            for dev in 0..d {
                assert!(
                    (des.finish(i, dev) - des.start(i, dev) - dur[dev]).abs() < 1e-12,
                    "node {i} duration accounting"
                );
                for dep in des_dag.deps_of(i) {
                    match op.stream() {
                        Stream::Comp => assert!(
                            des.start(i, dev) >= des.finish(dep, dev) - 1e-12,
                            "comp node {i} starts before dep {dep} on device {dev}"
                        ),
                        Stream::Comm => {
                            for dv in 0..d {
                                assert!(
                                    des.start(i, dev) >= des.finish(dep, dv) - 1e-12,
                                    "collective {i} starts before dep {dep} on device {dv}"
                                );
                            }
                        }
                    }
                }
            }
        }
        // Critical-path attribution is complete: exposed seconds sum to
        // the makespan.
        let sum: f64 = des.exposed.values().sum();
        assert!(
            (sum - des.makespan).abs() < 1e-9 * des.makespan.max(1e-9),
            "exposed {sum} vs makespan {}",
            des.makespan
        );
        let per_block: f64 = des.per_block_exposed.iter().sum();
        assert!((per_block - des.makespan).abs() < 1e-9 * des.makespan.max(1e-9));
    });
}

/// Bitwise DES-result comparison: every field of [`events::DesResult`]
/// must match exactly (f64s by `to_bits`; no NaN / −0.0 can occur on
/// valid DAGs, so `==` on device stats is bit-equality too).  Start and
/// finish instants are compared when both results retained them.
fn assert_des_bit_eq(
    a: &events::DesResult,
    b: &events::DesResult,
    n: usize,
    d: usize,
    what: &str,
) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
    assert_eq!(
        a.exposed.iter().map(|(k, v)| (*k, v.to_bits())).collect::<Vec<_>>(),
        b.exposed.iter().map(|(k, v)| (*k, v.to_bits())).collect::<Vec<_>>(),
        "{what}: exposed breakdown"
    );
    assert_eq!(
        a.per_block_exposed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b.per_block_exposed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{what}: per-block exposed"
    );
    assert_eq!(a.devices, b.devices, "{what}: device stats");
    assert_eq!(a.straggler, b.straggler, "{what}: straggler");
    if a.times.is_some() && b.times.is_some() {
        for i in 0..n {
            for dev in 0..d {
                assert_eq!(
                    a.start(i, dev).to_bits(),
                    b.start(i, dev).to_bits(),
                    "{what}: start[{i}][{dev}]"
                );
                assert_eq!(
                    a.finish(i, dev).to_bits(),
                    b.finish(i, dev).to_bits(),
                    "{what}: finish[{i}][{dev}]"
                );
            }
        }
    }
}

/// Three-way equivalence gate on one DAG: allocating `execute`, the
/// frozen `execute_reference`, and the hot `execute_with` over a scratch
/// reused across every case and shape this test generates.
fn assert_executors_agree(dag: &OpDag, scratch: &mut events::ExecScratch, what: &str) {
    let d = dag.n_devices;
    let fresh = events::execute(dag);
    let reference = events::execute_reference(dag);
    assert_des_bit_eq(&fresh, &reference, dag.len(), d, &format!("{what} (vs reference)"));
    let hot = events::execute_with(dag, scratch);
    assert!(hot.times.is_none(), "{what}: hot path must not retain times");
    assert_des_bit_eq(&hot, &reference, dag.len(), d, &format!("{what} (scratch reuse)"));
}

#[test]
fn prop_execute_matches_reference() {
    // The arena/scratch executor is a bit-exact refactor of the frozen
    // pre-arena implementation over ANY valid DAG: random unstructured
    // DAGs (mixed comp/comm ops, random backward dep subsets, durations
    // including exact zeros), barrier lowerings of random builder
    // schedules, and random Algorithm-2 relaxed DAGs — makespan,
    // breakdowns, device stats, straggler, and every start/finish
    // instant bitwise, with ONE ExecScratch carried across all cases
    // (stale capacity or contents must never leak between runs).
    let mut scratch = events::ExecScratch::new();
    Cases::new(64).run(move |rng| {
        // Unstructured random DAG.
        let d = 1 + rng.below(8);
        let n = 1 + rng.below(30);
        let mut random_dag = OpDag::new(d);
        for i in 0..n {
            let block = rng.below(3);
            let op = match rng.below(6) {
                0 => Op::Fec { block },
                1 => Op::Bnec { block },
                2 => Op::Plan { block },
                3 => Op::Trans { block, part: rng.below(2) as u8 },
                4 => Op::Agg { block, part: rng.below(2) as u8 },
                _ => Op::Fnec { block },
            };
            let dur: Vec<f64> = (0..d)
                .map(|_| if rng.below(5) == 0 { 0.0 } else { rng.f64() * 0.01 })
                .collect();
            let deps: Vec<usize> = (0..i).filter(|_| rng.below(4) == 0).collect();
            random_dag.push(op, dur, deps);
        }
        random_dag.validate().unwrap();
        assert_executors_agree(&random_dag, &mut scratch, "random DAG");

        // Barrier lowering of a random builder schedule (compressed
        // stage-barrier edges exercise the (lo, hi) range path).
        let n_blocks = 1 + rng.below(6);
        let blocks: Vec<BlockCosts> = (0..n_blocks).map(|_| random_block_costs(rng)).collect();
        let lowered = dag::from_schedule(&build_blockwise(&blocks), d);
        assert_executors_agree(&lowered, &mut scratch, "barrier lowering");

        // Random relaxed Algorithm-2 DAG (explicit CSR edges only).
        let devs: Vec<DeviceBlockCosts> =
            (0..n_blocks).map(|_| random_device_costs(rng, d)).collect();
        let mode = [SplitMode::Split, SplitMode::ExpertOnly, SplitMode::NonExpertOnly]
            [rng.below(3)];
        let relaxed = build_blockwise_dag(&devs, mode);
        assert_executors_agree(&relaxed, &mut scratch, "relaxed DAG");
    });
}

#[test]
fn prop_barrier_lowering_reproduces_stage_model_bitwise() {
    // Lowering any builder schedule to a barrier-shaped DAG with uniform
    // per-device durations and executing it reproduces total_time() and
    // exposed_breakdown() bit for bit — the DES-vs-Stage equivalence
    // oracle, over random costs and both builders.
    Cases::new(64).run(|rng| {
        let n_blocks = 1 + rng.below(10);
        let blocks: Vec<BlockCosts> = (0..n_blocks).map(|_| random_block_costs(rng)).collect();
        let d = 2 + rng.below(8);
        for sched in [
            build_blocking(&blocks, LoadBalanceOps::None),
            build_blocking(&blocks, LoadBalanceOps::Blocking),
            build_blockwise(&blocks),
        ] {
            let des = events::execute(&dag::from_schedule(&sched, d));
            assert_eq!(
                des.makespan.to_bits(),
                sched.total_time().to_bits(),
                "makespan != total_time"
            );
            let want = sched.exposed_breakdown();
            assert_eq!(
                des.exposed.keys().collect::<Vec<_>>(),
                want.keys().collect::<Vec<_>>(),
                "breakdown key sets differ"
            );
            for (k, v) in &want {
                assert_eq!(des.exposed[k].to_bits(), v.to_bits(), "breakdown[{k}]");
            }
        }
    });
}

#[test]
fn prop_relaxed_dag_bounded_by_barrier_and_compute() {
    // With uniform per-device costs the Algorithm-2 dependency DAG is
    // never slower than the barrier blockwise schedule (every DAG edge
    // is implied by a stage barrier) and never faster than the pure
    // compute + A2A lower bound.
    Cases::new(64).run(|rng| {
        let n_blocks = 1 + rng.below(8);
        let d = 2 + rng.below(6);
        let blocks: Vec<BlockCosts> = (0..n_blocks).map(|_| random_block_costs(rng)).collect();
        let dev: Vec<DeviceBlockCosts> =
            blocks.iter().map(|c| DeviceBlockCosts::uniform(c, d)).collect();
        let barrier = build_blockwise(&blocks).total_time();
        let des = events::execute(&build_blockwise_dag(&dev, SplitMode::Split));
        assert!(
            des.makespan <= barrier + 1e-9,
            "relaxed DAG {} slower than barrier {barrier}",
            des.makespan
        );
        let lower: f64 = blocks
            .iter()
            .map(|c| 4.0 * c.a2a + c.fec + c.bec + c.fnec + c.bnec)
            .sum();
        assert!(des.makespan >= lower - 1e-9, "DES {} under bound {lower}", des.makespan);
    });
}

/// `device_slowdown`-shaped heterogeneous costs: compute vectors scaled
/// per device, communication uniform (a slow GPU's NIC is not slower —
/// the engine's `*_per_device` semantics).
fn slowdown_scaled_costs(base: &BlockCosts, slow: &[f64]) -> DeviceBlockCosts {
    DeviceBlockCosts {
        a2a: vec![base.a2a; slow.len()],
        fec: slow.iter().map(|s| base.fec * s).collect(),
        bec: slow.iter().map(|s| base.bec * s).collect(),
        fnec: slow.iter().map(|s| base.fnec * s).collect(),
        bnec: slow.iter().map(|s| base.bnec * s).collect(),
        trans: vec![base.trans; slow.len()],
        agg: vec![base.agg; slow.len()],
        plan: vec![base.plan; slow.len()],
    }
}

#[test]
fn prop_schedule_kind_makespan_ordering() {
    // The schedule-kind axis, priced on IDENTICAL cost inputs:
    //   DagRelaxed <= Blockwise <= Blocking
    // over random block costs AND random heterogeneous `device_slowdown`
    // vectors (factors >= 1 — stragglers; compute scales, communication
    // does not, mirroring the engine).  All three kinds run on the
    // device-level DES exactly as `sim::simulate_policy` prices them:
    // the barrier kinds through the shape-preserving lowering
    // (`dag_from_schedule_with_costs`), DagRelaxed through the
    // Algorithm-2 true-dependency DAG.
    Cases::default().run(|rng| {
        let d = 2 + rng.below(7);
        let slow: Vec<f64> = (0..d)
            .map(|_| if rng.below(3) == 0 { 1.0 + rng.f64() * 3.0 } else { 1.0 })
            .collect();
        let n_layers = 1 + rng.below(6);
        let scalars: Vec<BlockCosts> =
            (0..n_layers).map(|_| random_block_costs(rng)).collect();
        let devs: Vec<DeviceBlockCosts> =
            scalars.iter().map(|c| slowdown_scaled_costs(c, &slow)).collect();
        let run_barrier = |schedule: &pro_prophet::scheduler::Schedule| -> f64 {
            events::execute(&dag_from_schedule_with_costs(schedule, &scalars, &devs, d))
                .makespan
        };
        let t_blocking = run_barrier(&build_blocking(&scalars, LoadBalanceOps::Blocking));
        let t_blockwise = run_barrier(&build_blockwise(&scalars));
        let t_relaxed =
            events::execute(&build_blockwise_dag(&devs, SplitMode::Split)).makespan;
        assert!(
            t_relaxed <= t_blockwise + 1e-9,
            "DagRelaxed {t_relaxed} slower than Blockwise {t_blockwise} (slow {slow:?})"
        );
        assert!(
            t_blockwise <= t_blocking + 1e-9,
            "Blockwise {t_blockwise} slower than Blocking {t_blocking} (slow {slow:?})"
        );
        // The relaxed timeline is still a real schedule: bounded below by
        // the compute + A2A critical path of the SLOWEST device.
        let lower: f64 = scalars
            .iter()
            .map(|c| {
                let worst = slow.iter().copied().fold(1.0f64, f64::max);
                4.0 * c.a2a + (c.fec + c.bec + c.fnec + c.bnec) * worst
            })
            .sum();
        assert!(
            t_relaxed >= lower - 1e-9,
            "DagRelaxed {t_relaxed} under the straggler lower bound {lower}"
        );
    });
}

#[test]
fn prop_planner_relaxed_bound_sound_and_tight_when_homogeneous() {
    // The planner's whole-iteration relaxed estimate
    // (`relaxed_makespan_bound`) is a SOUND upper bound of the executed
    // relaxed DAG on arbitrary per-device costs, and within a factor of
    // 2 on homogeneous (uniform-vector) clusters: with uniform durations
    // every node occupies every device's stream, so the makespan is at
    // least max(comp busy, comm busy) >= bound / 2.
    Cases::default().run(|rng| {
        let d = 2 + rng.below(7);
        let n_blocks = 1 + rng.below(6);
        let mode = [SplitMode::Split, SplitMode::ExpertOnly, SplitMode::NonExpertOnly]
            [rng.below(3)];
        // Arbitrary heterogeneous vectors: soundness only.
        let blocks: Vec<DeviceBlockCosts> =
            (0..n_blocks).map(|_| random_device_costs(rng, d)).collect();
        let des = events::execute(&build_blockwise_dag(&blocks, mode));
        let bound = relaxed_makespan_bound(&blocks, mode);
        assert!(
            des.makespan <= bound + 1e-9,
            "DES {} exceeds the planner bound {bound}",
            des.makespan
        );
        // Homogeneous vectors: soundness AND the 2x calibration band.
        let uniform: Vec<DeviceBlockCosts> = (0..n_blocks)
            .map(|_| DeviceBlockCosts::uniform(&random_block_costs(rng), d))
            .collect();
        let des_u = events::execute(&build_blockwise_dag(&uniform, mode));
        let bound_u = relaxed_makespan_bound(&uniform, mode);
        assert!(des_u.makespan <= bound_u + 1e-9);
        assert!(
            bound_u <= 2.0 * des_u.makespan + 1e-9,
            "bound {bound_u} looser than 2x the DES {}",
            des_u.makespan
        );
    });
}

#[test]
fn prop_slack_estimate_frozen_when_homogeneous() {
    // The slack-aware per-candidate estimate is bit-identical to the
    // frozen Eq-8 overlapped model on homogeneous clusters (so DagRelaxed
    // planning cannot perturb frozen decisions there), and charges
    // strictly more compute once a straggler exists (s = 0: no transfer
    // overflow terms to trade against).
    Cases::default().run(|rng| {
        let d = [4usize, 8, 16][rng.below(3)];
        let pm = pm_for(d);
        let max_h = rng.below(50_000) as u64;
        let max_r = rng.below(50_000) as u64;
        let s = rng.below(d + 1);
        let n = rng.below(d);
        let frozen = pm.layer_time_sn_from_maxes(max_h, max_r, s, n, true);
        let slack = pm.layer_time_sn_relaxed(max_h, max_r, s, n);
        assert_eq!(
            frozen.to_bits(),
            slack.to_bits(),
            "homogeneous slack estimate diverged: {frozen} vs {slack}"
        );
        // One straggler: the pure-compute estimate (s = 0) must grow.
        let factor = 1.5 + rng.f64() * 2.5;
        let cluster = ClusterSpec::hpwnv(d.div_ceil(4)).with_slowdown(rng.below(d), factor);
        let pm_het = PerfModel::new(
            &ModelSpec::moe_gpt_s(d, 1, 4096 * d as u64),
            &cluster,
        );
        assert_eq!(pm_het.max_slowdown(), factor);
        // Monotone in the slowdown for EVERY (s, n): the static non-MoE
        // windows are not scaled, so the window subtraction can never
        // outgrow the 3*t_fec charge (see layer_time_sn_relaxed docs).
        assert!(
            pm_het.layer_time_sn_relaxed(max_h, max_r, s, n)
                >= pm.layer_time_sn_relaxed(max_h, max_r, s, n) - 1e-12,
            "straggler lowered the slack estimate at s={s} n={n}"
        );
        if max_h > 0 {
            assert!(
                pm_het.layer_time_sn_relaxed(max_h, max_r, 0, 0)
                    > pm.layer_time_sn_relaxed(max_h, max_r, 0, 0),
                "straggler must raise the pure-compute slack estimate"
            );
        }
    });
}

#[test]
fn prop_similarity_bounds_and_symmetry() {
    Cases::default().run(|rng| {
        let n = 2 + rng.below(30);
        let a: Vec<u64> = (0..n).map(|_| rng.below(1000) as u64).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.below(1000) as u64).collect();
        let s_ab = locality::similarity(&a, &b);
        let s_ba = locality::similarity(&b, &a);
        assert!((0.0..=1.0 + 1e-12).contains(&s_ab));
        assert!((s_ab - s_ba).abs() < 1e-12, "similarity must be symmetric");
        assert!((locality::similarity(&a, &a) - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_trace_roundtrip_any_shape() {
    Cases::new(48).run(|rng| {
        let layers = 1 + rng.below(4);
        let d = 2 + rng.below(8);
        let e = 2 + rng.below(8);
        let iters = 1 + rng.below(4);
        let mut trace = Trace::new(layers, d, e);
        for _ in 0..iters {
            let ms: Vec<LoadMatrix> = (0..layers)
                .map(|_| {
                    let rows: Vec<Vec<u64>> = (0..d)
                        .map(|_| (0..e).map(|_| rng.below(500) as u64).collect())
                        .collect();
                    LoadMatrix::from_rows(rows)
                })
                .collect();
            trace.push(ms);
        }
        let back = Trace::deserialize(&trace.serialize()).unwrap();
        assert_eq!(trace, back);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    use pro_prophet::util::json::{self, Json};
    Cases::default().run(|rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Num((rng.below(100000) as f64) / 8.0 - 100.0),
                3 => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 0);
        let text = v.to_string();
        let back = json::parse(&text).unwrap();
        assert_eq!(v, back, "roundtrip failed for {text}");
    });
}

#[test]
fn prop_perfmodel_monotone_in_load() {
    Cases::default().run(|rng| {
        let d = 4 + rng.below(12);
        let pm = pm_for(d);
        let h: Vec<u64> = (0..d).map(|_| rng.below(5000) as u64).collect();
        let mut h2 = h.clone();
        let idx = rng.below(d);
        h2[idx] += 1000;
        assert!(pm.t_fec(&h2) >= pm.t_fec(&h));
        assert!(pm.t_a2a(&h2) >= pm.t_a2a(&h));
        // Scaling all loads scales the time linearly.
        let h3: Vec<u64> = h.iter().map(|&x| x * 3).collect();
        assert!((pm.t_fec(&h3) - 3.0 * pm.t_fec(&h)).abs() < 1e-12);
    });
}

/// Random trace of `iters` iterations on a d-device, d-expert shape.
fn random_trace(rng: &mut Rng, layers: usize, d: usize, iters: usize) -> Trace {
    let mut trace = Trace::new(layers, d, d);
    for _ in 0..iters {
        let ms: Vec<LoadMatrix> = (0..layers)
            .map(|_| {
                let per_device = 512 + rng.below(4096) as u64;
                let skew = 0.15 + rng.f64();
                let rows: Vec<Vec<u64>> = (0..d)
                    .map(|_| prop::random_histogram(rng, d, per_device, skew))
                    .collect();
                LoadMatrix::from_rows(rows)
            })
            .collect();
        trace.push(ms);
    }
    trace
}

#[test]
fn prop_des_makespan_monotone_in_device_slowdown() {
    // Slowing any single device further can never make the device-level
    // event timeline finish earlier: the operator DAG and its device
    // assignment are fixed (deepspeed decides independently of pricing),
    // so the makespan is monotone in per-op durations.
    Cases::new(16).run(|rng| {
        let d = [4usize, 8][rng.below(2)];
        let layers = 1 + rng.below(2);
        let trace = random_trace(rng, layers, d, 2 + rng.below(2));
        let model = ModelSpec::moe_gpt_s(d, 1, 4096 * d as u64);
        let dev = rng.below(d);
        let base = 1.0 + rng.f64() * 2.0;
        let worse = base * (1.25 + rng.f64());
        let run = |factor: f64| {
            let cluster = ClusterSpec::hpwnv(d.div_ceil(4)).with_slowdown(dev, factor);
            pro_prophet::sim::simulate_policy(
                &model,
                &cluster,
                &trace,
                Box::new(pro_prophet::balancer::builtin::DeepspeedMoe),
            )
        };
        let ra = run(base);
        let rb = run(worse);
        for (i, (a, b)) in ra.iters.iter().zip(&rb.iters).enumerate() {
            assert!(
                b.des_time >= a.des_time - 1e-12,
                "iter {i}: DES makespan decreased when device {dev} slowed \
                 {base} -> {worse}: {} -> {}",
                a.des_time,
                b.des_time
            );
        }
    });
}

#[test]
fn prop_transient_straggler_tracked_only_inside_its_window() {
    // A transient slowdown injected on device `dev` must surface as
    // `IterationResult::straggler == dev` exactly while the fault is
    // active; every iteration outside the window stays bit-identical to
    // the no-fault run (same straggler, same time).  Near-uniform loads
    // plus a large factor make the injected device's dominance certain.
    use pro_prophet::faults::FaultTimeline;
    use pro_prophet::sim::{simulate_policy_faulted, SimOptions};
    Cases::new(12).run(|rng| {
        let d = [4usize, 8][rng.below(2)];
        let iters = 5 + rng.below(3);
        let mut trace = Trace::new(1, d, d);
        for _ in 0..iters {
            let rows: Vec<Vec<u64>> =
                (0..d).map(|_| (0..d).map(|_| 400 + rng.below(100) as u64).collect()).collect();
            trace.push(vec![LoadMatrix::from_rows(rows)]);
        }
        let model = ModelSpec::moe_gpt_s(d, 1, 4096 * d as u64);
        let cluster = ClusterSpec::hpwnv(d.div_ceil(4));
        let dev = rng.below(d);
        let start = 1 + rng.below(3);
        let dur = 1 + rng.below(3);
        let factor = 8.0 + rng.f64() * 8.0;
        let spec = format!("transient dev={dev} factor={factor} start={start} dur={dur}");
        let faults = FaultTimeline::parse_specs(&[spec], d).unwrap();

        let baseline = pro_prophet::sim::simulate_policy(
            &model,
            &cluster,
            &trace,
            Box::new(pro_prophet::balancer::builtin::DeepspeedMoe),
        );
        let faulted = simulate_policy_faulted(
            &model,
            &cluster,
            &trace,
            Box::new(pro_prophet::balancer::builtin::DeepspeedMoe),
            pro_prophet::obs::noop_arc(),
            &SimOptions { faults, ..Default::default() },
        )
        .unwrap();

        for i in 0..trace.len() {
            let (a, b) = (&baseline.iters[i], &faulted.iters[i]);
            if (start..start + dur).contains(&i) {
                assert_eq!(
                    b.straggler, dev,
                    "iter {i}: straggler must be the injected device {dev}"
                );
                assert_eq!(
                    b.time.to_bits(),
                    b.des_time.to_bits(),
                    "iter {i}: fault window must be DES-priced"
                );
                assert!(
                    b.time >= a.time,
                    "iter {i}: slowing a device cannot speed the iteration up"
                );
            } else {
                assert_eq!(
                    a.time.to_bits(),
                    b.time.to_bits(),
                    "iter {i}: outside the window must match the no-fault run"
                );
                assert_eq!(a.straggler, b.straggler, "iter {i}: straggler outside window");
            }
        }
    });
}
